//! Content-addressed solve memoization.
//!
//! Back-to-back control-plane triggers often rebuild a byte-identical
//! [`Instance`] (the GPO snapshot didn't change between them, or churned
//! and reverted). [`SolveCache`] keys solutions by an FNV-1a digest of
//! the instance's canonical bytes plus a canonicalized [`SolveOptions`],
//! so such triggers return the already-installed plan in O(hash) instead
//! of re-running the solver. Hits are byte-identical to a recompute by
//! construction — the cached value IS a prior recompute for the same
//! content key, and every keyed field is hashed at full `f64` bit
//! precision.
//!
//! Key scheme (DESIGN.md §10): every solver-visible field participates
//! except the two that cannot steer a deterministic result —
//! `bb.time_limit_s` (wall-clock termination; configurations carrying it
//! bypass the cache entirely rather than risk sharing entries between
//! divergent runs) and `shard.workers` (thread count changes wall time
//! only, never the result). The shard `root_seed` IS hashed: different
//! seeds explore different restarts. Both hash helpers destructure their
//! structs exhaustively, so adding a field fails compilation here and
//! forces a decision about whether it belongs in the key.

use std::collections::{BTreeMap, VecDeque};

use super::local_search::{LocalSearchOptions, LsMode};
use super::{solve, BbOptions, Mode, ShardOptions, SolveError, SolveOptions, Solution};
use crate::hflop::Instance;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a over the canonical byte encoding of the key fields.
struct Fnv(u64);

impl Fnv {
    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
}

/// Bounded content-addressed memo of [`solve`] results, FIFO-evicted.
#[derive(Debug)]
pub struct SolveCache {
    capacity: usize,
    entries: BTreeMap<u64, Solution>,
    order: VecDeque<u64>,
    hits: u64,
    misses: u64,
    bypasses: u64,
}

impl SolveCache {
    /// A cache holding at most `capacity` solutions (min 1).
    pub fn new(capacity: usize) -> SolveCache {
        SolveCache {
            capacity: capacity.max(1),
            entries: BTreeMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
            bypasses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Served from the memo without solving.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Solved cold and stored.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Solved cold and NOT stored (uncacheable options).
    pub fn bypasses(&self) -> u64 {
        self.bypasses
    }

    /// Whether `opts` may use the cache at all. Wall-clock-limited
    /// configurations are machine-dependent, so their results are never
    /// stored or served.
    pub fn cacheable(opts: &SolveOptions) -> bool {
        opts.bb.time_limit_s.is_none()
    }

    /// The content key for `(inst, opts)`. Only meaningful when
    /// [`cacheable`](Self::cacheable) holds.
    pub fn key(inst: &Instance, opts: &SolveOptions) -> u64 {
        let mut h = Fnv(FNV_OFFSET);
        hash_instance(&mut h, inst);
        hash_options(&mut h, opts);
        h.0
    }

    /// The memoized solution for `key`, if present (cloned).
    pub fn get(&mut self, key: u64) -> Option<Solution> {
        let hit = self.entries.get(&key).cloned();
        if hit.is_some() {
            self.hits += 1;
        }
        hit
    }

    /// Store a cold result under `key`, evicting the oldest entry past
    /// capacity. Overwrites silently (same key ⇒ same content).
    pub fn put(&mut self, key: u64, sol: Solution) {
        self.misses += 1;
        if self.entries.insert(key, sol).is_none() {
            self.order.push_back(key);
            if self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    self.entries.remove(&old);
                }
            }
        }
    }

    /// Memoized [`solve`]: serve a hit when the content key is known,
    /// otherwise solve cold and store. Uncacheable options pass straight
    /// through to the solver.
    pub fn solve(&mut self, inst: &Instance, opts: &SolveOptions) -> Result<Solution, SolveError> {
        if !Self::cacheable(opts) {
            self.bypasses += 1;
            return solve(inst, opts);
        }
        let key = Self::key(inst, opts);
        if let Some(sol) = self.get(key) {
            return Ok(sol);
        }
        let sol = solve(inst, opts)?;
        self.put(key, sol.clone());
        Ok(sol)
    }
}

/// Canonical bytes of everything the solver reads from the instance.
/// `meta` is excluded: it caches validation/feasibility bookkeeping
/// derived from the fields already hashed.
fn hash_instance(h: &mut Fnv, inst: &Instance) {
    let Instance { c_d, c_e, lambda, r, l, t_min, meta: _ } = inst;
    h.usize(c_d.rows());
    h.usize(c_d.cols());
    for &v in c_d.as_slice() {
        h.f64(v);
    }
    for &v in c_e.iter() {
        h.f64(v);
    }
    for &v in lambda.iter() {
        h.f64(v);
    }
    for &v in r.iter() {
        h.f64(v);
    }
    h.f64(*l);
    h.usize(*t_min);
}

/// Canonicalized options: every result-steering field, nothing else.
fn hash_options(h: &mut Fnv, opts: &SolveOptions) {
    let SolveOptions { mode, bb, ls, auto_exact_below, auto_sharded_above, shard, deterministic } =
        opts;
    h.u64(match mode {
        Mode::Exact => 0,
        Mode::Heuristic => 1,
        Mode::Sharded => 2,
        Mode::Auto => 3,
    });
    let BbOptions { disaggregate_below, node_limit, time_limit_s, abs_gap } = bb;
    h.usize(*disaggregate_below);
    h.usize(*node_limit);
    // `time_limit_s` is deliberately NOT hashed: wall-clock termination
    // is machine-dependent, so `cacheable` keeps such configurations out
    // of the cache entirely — hashing the field would only suggest that
    // two limited runs are interchangeable.
    let _ = time_limit_s;
    h.f64(*abs_gap);
    let LocalSearchOptions { max_rounds, mode: ls_mode } = ls;
    h.usize(*max_rounds);
    h.u64(match ls_mode {
        LsMode::Auto => 0,
        LsMode::Completion => 1,
        LsMode::Incremental => 2,
    });
    h.usize(*auto_exact_below);
    h.usize(*auto_sharded_above);
    let ShardOptions { regions, root_seed, workers, restarts, repair_sweeps } = shard;
    h.usize(*regions);
    // The seed IS part of the key: different seeds explore different
    // sharded restarts and may legitimately return different plans.
    h.u64(*root_seed);
    // `workers` is deliberately NOT hashed: thread count changes wall
    // time only, never the result (pinned by sharded equivalence tests).
    let _ = workers;
    h.usize(*restarts);
    h.usize(*repair_sweeps);
    h.u64(u64::from(*deterministic));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;

    fn inst(seed: u64) -> Instance {
        InstanceBuilder::random(30, 5, seed).t_min(24).build()
    }

    #[test]
    fn key_ignores_wall_clock_and_worker_count() {
        let i = inst(1);
        let base = SolveOptions::heuristic();
        let k0 = SolveCache::key(&i, &base);

        let mut timed = base.clone();
        timed.bb.time_limit_s = Some(9.0);
        assert_eq!(k0, SolveCache::key(&i, &timed), "time_limit_s must not reach the key");
        // ...but such options never touch the cache in the first place.
        assert!(!SolveCache::cacheable(&timed));
        assert!(SolveCache::cacheable(&base));

        let mut threaded = base.clone();
        threaded.shard.workers = 8;
        assert_eq!(k0, SolveCache::key(&i, &threaded), "workers must not reach the key");
    }

    #[test]
    fn key_includes_every_result_steering_field() {
        let i = inst(2);
        let base = SolveOptions::heuristic();
        let k0 = SolveCache::key(&i, &base);

        // One mutation per result-steering SolveOptions field. Keep this
        // list in sync with the exhaustive destructures above — a new
        // field breaks compilation there, then gets a row here.
        let mutations: Vec<(&str, fn(&mut SolveOptions))> = vec![
            ("mode", |o| o.mode = Mode::Exact),
            ("bb.disaggregate_below", |o| o.bb.disaggregate_below += 1),
            ("bb.node_limit", |o| o.bb.node_limit += 1),
            ("bb.abs_gap", |o| o.bb.abs_gap += 0.5),
            ("ls.max_rounds", |o| o.ls.max_rounds += 1),
            ("ls.mode", |o| o.ls.mode = LsMode::Incremental),
            ("auto_exact_below", |o| o.auto_exact_below += 1),
            ("auto_sharded_above", |o| o.auto_sharded_above += 1),
            ("shard.regions", |o| o.shard.regions += 1),
            ("shard.root_seed", |o| o.shard.root_seed += 1),
            ("shard.restarts", |o| o.shard.restarts += 1),
            ("shard.repair_sweeps", |o| o.shard.repair_sweeps += 1),
            ("deterministic", |o| o.deterministic = false),
        ];
        // SolveOptions carries 15 result-relevant-or-not leaf fields;
        // 13 steer results, 2 (time_limit_s, workers) do not.
        assert_eq!(mutations.len(), 13);
        for (name, mutate) in mutations {
            let mut opts = base.clone();
            mutate(&mut opts);
            assert_ne!(k0, SolveCache::key(&i, &opts), "field '{name}' must change the key");
        }
    }

    #[test]
    fn key_is_content_addressed_over_the_instance() {
        let opts = SolveOptions::heuristic();
        let a = inst(3);
        assert_eq!(SolveCache::key(&a, &opts), SolveCache::key(&a.clone(), &opts));
        assert_ne!(SolveCache::key(&a, &opts), SolveCache::key(&inst(4), &opts));

        let mut surged = a.clone();
        surged.lambda[0] *= 2.0;
        surged.meta = Default::default();
        assert_ne!(SolveCache::key(&a, &opts), SolveCache::key(&surged, &opts));

        let mut squeezed = a.clone();
        squeezed.r[1] *= 0.5;
        squeezed.meta = Default::default();
        assert_ne!(SolveCache::key(&a, &opts), SolveCache::key(&squeezed, &opts));
    }

    #[test]
    fn hit_is_byte_identical_to_a_recompute() {
        let i = inst(5);
        let opts = SolveOptions::heuristic();
        let mut cache = SolveCache::new(4);
        let first = cache.solve(&i, &opts).unwrap();
        let hit = cache.solve(&i, &opts).unwrap();
        let fresh = solve(&i, &opts).unwrap();
        assert_eq!(hit.assignment, fresh.assignment);
        assert_eq!(hit.cost.to_bits(), fresh.cost.to_bits());
        assert_eq!(hit.assignment, first.assignment);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn uncacheable_options_bypass_without_storing() {
        let i = inst(6);
        let mut opts = SolveOptions::exact();
        opts.deterministic = false;
        opts.bb.time_limit_s = Some(60.0);
        let mut cache = SolveCache::new(4);
        cache.solve(&i, &opts).unwrap();
        cache.solve(&i, &opts).unwrap();
        assert!(cache.is_empty());
        assert_eq!(cache.bypasses(), 2);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn capacity_bound_evicts_oldest_first() {
        let opts = SolveOptions::heuristic();
        let (a, b, c) = (inst(7), inst(8), inst(9));
        let mut cache = SolveCache::new(2);
        cache.solve(&a, &opts).unwrap();
        cache.solve(&b, &opts).unwrap();
        cache.solve(&c, &opts).unwrap();
        assert_eq!(cache.len(), 2);
        // `a` was evicted: solving it again is a miss, not a hit.
        cache.solve(&a, &opts).unwrap();
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.misses(), 4);
    }
}
