//! Warm-start re-solve: repair a previous assignment against a mutated
//! instance and polish it with a dirty-restricted local search.
//!
//! The control plane re-solves on every environmental trigger (fault,
//! recovery, capacity report, surge). Between consecutive triggers only a
//! handful of rows/columns actually change, so a cold
//! [`solve`](super::solve) re-derives an almost-identical plan from
//! scratch. [`resolve`] instead repairs the incumbent in O(changed):
//! drop assignments to closed columns, evict overloads λ-descending onto
//! residual capacity, reseat the displaced devices greedily, then run
//! first-improvement sweeps restricted to the dirty rows/columns and
//! whatever the repair touched. Invariants (DESIGN.md §10): the result is
//! always feasible for the *new* instance or an error, never a silently
//! degraded plan; identical `(inst, prev, dirty)` inputs produce
//! bit-identical outputs.

use super::solution::{close_empty_edges, IncrementalEvaluator};
use super::{Assignment, SolveError, SolveOptions, Solution};
use crate::hflop::Instance;

/// Rows (devices) and columns (edges) that changed since the incumbent
/// was installed: capacity, λ, liveness, or membership. Entries are
/// instance-local indices, each list sorted ascending and duplicate-free.
#[derive(Debug, Clone, Default)]
pub struct DirtySet {
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
}

impl DirtySet {
    /// Nothing changed.
    pub fn empty() -> DirtySet {
        DirtySet::default()
    }

    /// Everything changed — degrades [`resolve`] to a full-neighborhood
    /// repair, still seeded from the incumbent.
    pub fn all(n: usize, m: usize) -> DirtySet {
        DirtySet { rows: (0..n).collect(), cols: (0..m).collect() }
    }

    /// Fraction of the instance that is dirty, in `[0, 1]` — the `Auto`
    /// strategy's warm-vs-cold pivot.
    pub fn fraction(&self, n: usize, m: usize) -> f64 {
        if n + m == 0 {
            return 0.0;
        }
        let dirty = (self.rows.len() + self.cols.len()) as f64;
        (dirty / (n + m) as f64).min(1.0)
    }
}

/// Warm-start re-solve: repair `prev` against `inst` and polish with a
/// search restricted to `dirty` rows/columns (plus anything the repair
/// itself displaced). Heuristic by construction — `proven_optimal` is
/// always false, even when `prev` was exact.
///
/// Errors mirror [`solve`](super::solve): `Invalid` on shape/content
/// mismatch, `Infeasible` when the repaired plan cannot reach `t_min`
/// participation. On `Infeasible` the caller should fall back to a cold
/// solve or keep the stale plan (the control plane does the latter when
/// both fail).
pub fn resolve(
    inst: &Instance,
    prev: &Solution,
    dirty: &DirtySet,
    opts: &SolveOptions,
) -> Result<Solution, SolveError> {
    resolve_assignment(inst, &prev.assignment, dirty, opts)
}

/// [`resolve`] taking the bare incumbent assignment — what the
/// orchestrator holds after projecting an installed plan onto a freshly
/// built instance (the plan's cost is stale there, so a full `Solution`
/// would be a lie).
pub fn resolve_assignment(
    inst: &Instance,
    prev: &Assignment,
    dirty: &DirtySet,
    opts: &SolveOptions,
) -> Result<Solution, SolveError> {
    super::check_deterministic(opts)?;
    if inst.meta.validated {
        debug_assert!(inst.validate().is_ok(), "validated instance failed re-validation");
    } else {
        inst.validate().map_err(|e| SolveError::Invalid(e.to_string()))?;
    }
    let (n, m) = (inst.n(), inst.m());
    if prev.assign.len() != n || prev.open.len() != m {
        return Err(SolveError::Invalid(format!(
            "warm start shape mismatch: incumbent is {}x{}, instance is {n}x{m}",
            prev.assign.len(),
            prev.open.len()
        )));
    }
    if dirty.rows.iter().any(|&i| i >= n) || dirty.cols.iter().any(|&j| j >= m) {
        return Err(SolveError::Invalid("dirty set indexes outside the instance".into()));
    }
    if !inst.capacity_feasible() {
        return Err(SolveError::Infeasible("aggregate capacity below t_min demand".into()));
    }

    let (best, wall_s) = crate::util::time_it(|| repair(inst, prev, dirty));
    match best {
        Some(assignment) => {
            // Final cost is a full recompute, not the evaluator's running
            // sum: warm and cold paths must agree bit-for-bit on cost
            // whenever they agree on the assignment.
            let cost = assignment.cost(inst);
            Ok(Solution { assignment, cost, proven_optimal: false, nodes: 0, wall_s })
        }
        None => Err(SolveError::Infeasible(
            "warm-start repair fell below t_min participation".into(),
        )),
    }
}

/// Cheapest open column with residual for device `i`, ties broken
/// toward the larger residual — `complete_assignment`'s seat rule.
fn best_open_column(ev: &IncrementalEvaluator, inst: &Instance, i: usize) -> Option<usize> {
    let row = inst.c_d.row(i);
    let lam = inst.lambda[i];
    let mut best: Option<usize> = None;
    for j in 0..inst.m() {
        if !ev.is_open(j) || ev.residual(j) + 1e-9 < lam {
            continue;
        }
        best = Some(match best {
            None => j,
            Some(b) => {
                let better = row[j] < row[b] - 1e-12
                    || (row[j] < row[b] + 1e-12 && ev.residual(j) > ev.residual(b));
                if better {
                    j
                } else {
                    b
                }
            }
        });
    }
    best
}

/// The repair pipeline. Returns `None` when the repaired plan cannot
/// seat `t_min` devices.
fn repair(inst: &Instance, prev: &Assignment, dirty: &DirtySet) -> Option<Assignment> {
    let (n, m) = (inst.n(), inst.m());

    // 1. Sanitize the incumbent: assignments to closed columns are
    //    dropped. The orchestrator's projection normally leaves `None`
    //    there already; this keeps hand-built incumbents safe too.
    let mut seed = prev.clone();
    let mut dropped: Vec<usize> = Vec::new();
    for (i, a) in seed.assign.iter_mut().enumerate() {
        if let Some(j) = *a {
            if !seed.open[j] {
                *a = None;
                dropped.push(i);
            }
        }
    }
    let mut ev = IncrementalEvaluator::new(inst, &seed);

    // 2. Evict overloads: a column whose capacity shrank (or whose
    //    devices surged) sheds its largest-λ devices first — fewest
    //    evictions restore feasibility. The evaluator tolerates the
    //    transient negative residual.
    let mut evicted: Vec<usize> = Vec::new();
    for j in 0..m {
        if !ev.is_open(j) || ev.residual(j) >= -1e-9 {
            continue;
        }
        let mut on_j: Vec<usize> = (0..n).filter(|&i| ev.assign_of(i) == Some(j)).collect();
        on_j.sort_by(|&a, &b| inst.lambda[b].total_cmp(&inst.lambda[a]).then(a.cmp(&b)));
        for &i in &on_j {
            if ev.residual(j) >= -1e-9 {
                break;
            }
            ev.apply_unassign(i);
            evicted.push(i);
        }
    }

    // 3. Reseat the *displaced* devices (sanitize drops + evictions)
    //    λ-descending into the OPEN columns, mirroring
    //    `complete_assignment`: cheapest column with residual, ties to
    //    the larger residual. Devices the incumbent left unassigned stay
    //    unassigned — repair preserves the incumbent's participation
    //    choices rather than re-running assign-max (which would perturb
    //    rows the churn never touched), except where t_min forces more
    //    seats below.
    let mut reseated: Vec<usize> = dropped;
    reseated.extend_from_slice(&evicted);
    reseated.sort_by(|&a, &b| inst.lambda[b].total_cmp(&inst.lambda[a]).then(a.cmp(&b)));
    let mut overflow: Vec<usize> = Vec::new();
    for &i in &reseated {
        match best_open_column(&ev, inst, i) {
            Some(j) => {
                ev.apply_assign(i, j);
            }
            None => overflow.push(i),
        }
    }
    if ev.n_assigned() < inst.t_min {
        // Participation repair: seat smallest-λ unassigned devices first
        // (most seats per unit of capacity), opening the closed column
        // that minimizes assignment-plus-opening cost when no open
        // column fits. Draws from every unassigned device — not just the
        // displaced ones — because reaching t_min outranks preserving
        // the incumbent's participation choices.
        let mut pending: Vec<usize> =
            (0..n).filter(|&i| ev.assign_of(i).is_none()).collect();
        pending.sort_by(|&a, &b| inst.lambda[a].total_cmp(&inst.lambda[b]).then(a.cmp(&b)));
        for &i in &pending {
            if ev.n_assigned() >= inst.t_min {
                break;
            }
            if let Some(j) = best_open_column(&ev, inst, i) {
                ev.apply_assign(i, j);
                reseated.push(i);
                continue;
            }
            let row = inst.c_d.row(i);
            let lam = inst.lambda[i];
            let mut cand: Option<usize> = None;
            for j in 0..m {
                if ev.is_open(j) || ev.residual(j) + 1e-9 < lam {
                    continue;
                }
                let score = inst.l * row[j] + inst.c_e[j];
                cand = Some(match cand {
                    None => j,
                    Some(b) => {
                        if score < inst.l * row[b] + inst.c_e[b] - 1e-12 {
                            j
                        } else {
                            b
                        }
                    }
                });
            }
            if let Some(j) = cand {
                ev.open_edge(j);
                ev.apply_assign(i, j);
                reseated.push(i);
            }
        }
        if ev.n_assigned() < inst.t_min {
            return None;
        }
        // Assign-max epilogue: a column opened for t_min may have spare
        // residual; seat remaining overflow devices in it (λ-descending,
        // the order `overflow` is already in).
        for &i in &overflow {
            if ev.assign_of(i).is_some() {
                continue;
            }
            if let Some(j) = best_open_column(&ev, inst, i) {
                ev.apply_assign(i, j);
            }
        }
    }

    // 4. Restricted neighborhood: dirty rows, rows the repair displaced,
    //    and rows currently parked on a dirty column.
    let mut touched = vec![false; n];
    for &i in dirty.rows.iter().chain(&evicted).chain(&reseated) {
        touched[i] = true;
    }
    let mut col_dirty = vec![false; m];
    for &j in &dirty.cols {
        col_dirty[j] = true;
    }
    for i in 0..n {
        if let Some(j) = ev.assign_of(i) {
            if col_dirty[j] {
                touched[i] = true;
            }
        }
    }
    let rows: Vec<usize> = (0..n).filter(|&i| touched[i]).collect();

    // 4a. First-improvement reassignment sweeps over the touched rows
    //     only — the same move rule and tolerances as `refine_in_place`,
    //     with the same sweep cap.
    for _sweep in 0..20 {
        let mut improved = false;
        for &i in &rows {
            let Some(cur) = ev.assign_of(i) else { continue };
            let row = inst.c_d.row(i);
            let mut best: Option<usize> = None;
            for j in 0..m {
                if j == cur || !ev.is_open(j) {
                    continue;
                }
                if row[j] < row[cur] - 1e-12 && ev.residual(j) + 1e-9 >= inst.lambda[i] {
                    let better = match best {
                        None => true,
                        Some(b) => row[j] < row[b],
                    };
                    if better {
                        best = Some(j);
                    }
                }
            }
            if let Some(j) = best {
                ev.apply_reassign(i, j);
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    // 4b. Facility move restricted to dirty columns: speculatively open
    //     each dirty closed column, pull strictly-improving touched rows
    //     onto it, and keep the transaction only when it pays for the
    //     opening fee. Rollback re-applies the moves in reverse (each
    //     device returns to a column whose capacity it just vacated) and
    //     pins the evaluator cost back to the checkpoint.
    for &j in &dirty.cols {
        if ev.is_open(j) || inst.r[j] <= 0.0 {
            continue;
        }
        let checkpoint = ev.cost();
        ev.open_edge(j);
        let mut moves: Vec<(usize, usize)> = Vec::new();
        for &i in &rows {
            let Some(cur) = ev.assign_of(i) else { continue };
            let row = inst.c_d.row(i);
            if cur != j
                && row[j] < row[cur] - 1e-12
                && ev.residual(j) + 1e-9 >= inst.lambda[i]
            {
                ev.apply_reassign(i, j);
                moves.push((i, cur));
            }
        }
        if ev.cost() < checkpoint - 1e-9 {
            continue;
        }
        for &(i, cur) in moves.iter().rev() {
            ev.apply_reassign(i, cur);
        }
        ev.close_edge(j);
        ev.reset_cost(checkpoint);
    }

    close_empty_edges(&mut ev);
    let out = ev.assignment();
    debug_assert!(
        out.check_feasible(inst).is_ok(),
        "warm repair produced an infeasible assignment: {:?}",
        out.check_feasible(inst)
    );
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::{solve, SolveOptions};

    fn base(seed: u64) -> Instance {
        InstanceBuilder::random(24, 4, seed).t_min(18).build()
    }

    #[test]
    fn unchanged_instance_reproduces_incumbent() {
        let inst = base(1);
        let cold = solve(&inst, &SolveOptions::heuristic()).unwrap();
        let warm =
            resolve(&inst, &cold, &DirtySet::empty(), &SolveOptions::heuristic()).unwrap();
        // Nothing was dirty, so the restricted search had nothing to
        // move: the incumbent survives bit-for-bit.
        assert_eq!(warm.assignment, cold.assignment);
        assert_eq!(warm.cost.to_bits(), cold.cost.to_bits());
        assert!(!warm.proven_optimal);
    }

    #[test]
    fn dead_column_devices_are_rehomed() {
        let inst = base(2);
        let cold = solve(&inst, &SolveOptions::heuristic()).unwrap();
        let mut churned = inst.clone();
        churned.r[0] = 0.0;
        churned.meta = Default::default();
        let dirty = DirtySet { rows: Vec::new(), cols: vec![0] };
        let warm = resolve(&churned, &cold, &dirty, &SolveOptions::heuristic()).unwrap();
        warm.assignment.check_feasible(&churned).unwrap();
        assert!(!warm.assignment.open[0], "zero-capacity column must end closed");
        assert!((0..churned.n()).all(|i| warm.assignment.assign[i] != Some(0)));
    }

    #[test]
    fn shape_mismatch_is_invalid() {
        let inst = base(3);
        let other = InstanceBuilder::random(10, 3, 3).t_min(8).build();
        let cold = solve(&other, &SolveOptions::heuristic()).unwrap();
        let err = resolve(&inst, &cold, &DirtySet::empty(), &SolveOptions::heuristic());
        assert!(matches!(err, Err(SolveError::Invalid(_))));
    }

    #[test]
    fn out_of_range_dirty_set_is_invalid() {
        let inst = base(4);
        let cold = solve(&inst, &SolveOptions::heuristic()).unwrap();
        let dirty = DirtySet { rows: vec![inst.n()], cols: Vec::new() };
        let err = resolve(&inst, &cold, &dirty, &SolveOptions::heuristic());
        assert!(matches!(err, Err(SolveError::Invalid(_))));
    }

    #[test]
    fn capacity_collapse_is_infeasible() {
        let inst = base(5);
        let cold = solve(&inst, &SolveOptions::heuristic()).unwrap();
        let mut churned = inst.clone();
        for j in 0..churned.m() {
            churned.r[j] = 0.0;
        }
        churned.meta = Default::default();
        let dirty = DirtySet::all(churned.n(), churned.m());
        let err = resolve(&churned, &cold, &dirty, &SolveOptions::heuristic());
        assert!(matches!(err, Err(SolveError::Infeasible(_))));
    }

    #[test]
    fn fraction_is_bounded() {
        assert_eq!(DirtySet::empty().fraction(10, 5), 0.0);
        assert_eq!(DirtySet::all(10, 5).fraction(10, 5), 1.0);
        let half = DirtySet { rows: vec![0, 1, 2], cols: Vec::new() };
        assert!((half.fraction(3, 3) - 0.5).abs() < 1e-12);
        assert_eq!(DirtySet::empty().fraction(0, 0), 0.0);
    }
}
