//! Exhaustive HFLOP solver — the test oracle for tiny instances.
//!
//! Enumerates every open-edge subset (2^m) and, per subset, every feasible
//! device assignment by depth-first search with cost pruning. Exponential
//! in both n and m; use only where n ≤ ~10 and m ≤ ~4 (tests compare the
//! branch & bound against this).

use super::solution::Assignment;
use crate::hflop::Instance;

/// Exact optimum by exhaustive search. Returns `(assignment, cost)` or
/// None if infeasible.
pub fn brute_force(inst: &Instance) -> Option<(Assignment, f64)> {
    let (n, m) = (inst.n(), inst.m());
    assert!(m < 16, "brute_force: m too large");
    let mut best: Option<(Assignment, f64)> = None;

    for mask in 0u32..(1 << m) {
        let open: Vec<bool> = (0..m).map(|j| mask & (1 << j) != 0).collect();
        let open_cost: f64 = (0..m).filter(|&j| open[j]).map(|j| inst.c_e[j]).sum();
        let best_cost = best.as_ref().map(|b| b.1).unwrap_or(f64::INFINITY);
        if open_cost >= best_cost {
            continue;
        }
        let open_list: Vec<usize> = (0..m).filter(|&j| open[j]).collect();
        // DFS over devices: assign to an open edge or leave unassigned.
        let mut assign = vec![None; n];
        let mut residual: Vec<f64> = inst.r.to_vec();
        let mut found: Option<(Vec<Option<usize>>, f64)> = None;
        dfs(
            inst,
            &open_list,
            0,
            0,
            open_cost,
            &mut assign,
            &mut residual,
            &mut found,
            best_cost,
        );
        if let Some((assignment, cost)) = found {
            // Empty open edges make the solution formally infeasible
            // (constraint 3); skip those (the equivalent closed-subset
            // mask covers the same assignment).
            let ok = open_list
                .iter()
                .all(|&j| assignment.iter().any(|&a| a == Some(j)));
            if ok && cost < best_cost {
                let sol = Assignment { assign: assignment, open: open.clone() };
                debug_assert!(sol.check_feasible(inst).is_ok(), "{:?}", sol.check_feasible(inst));
                best = Some((sol, cost));
            }
        }
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn dfs(
    inst: &Instance,
    open: &[usize],
    i: usize,
    assigned: usize,
    cost: f64,
    assign: &mut Vec<Option<usize>>,
    residual: &mut Vec<f64>,
    best: &mut Option<(Vec<Option<usize>>, f64)>,
    global_best: f64,
) {
    let n = inst.n();
    let cutoff = best.as_ref().map(|b| b.1).unwrap_or(global_best);
    if cost >= cutoff {
        return;
    }
    if i == n {
        if assigned >= inst.t_min {
            *best = Some((assign.clone(), cost));
        }
        return;
    }
    // Prune: even assigning every remaining device can't reach t_min.
    if assigned + (n - i) < inst.t_min {
        return;
    }
    // Try each open edge.
    for &j in open {
        if residual[j] + 1e-9 >= inst.lambda[i] {
            residual[j] -= inst.lambda[i];
            assign[i] = Some(j);
            dfs(
                inst,
                open,
                i + 1,
                assigned + 1,
                cost + inst.l * inst.c_d[i][j],
                assign,
                residual,
                best,
                global_best,
            );
            assign[i] = None;
            residual[j] += inst.lambda[i];
        }
    }
    // Leave unassigned (allowed if t_min still reachable — checked above).
    dfs(inst, open, i + 1, assigned, cost, assign, residual, best, global_best);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::{Instance, InstanceBuilder};

    #[test]
    fn hand_solvable_instance() {
        // 2 devices, 2 edges. Device i free at edge i, expensive across.
        // Opening both: cost c_e = 2, local 0. Opening one: c_e 1 + one
        // remote assignment l*1 = 2 -> total 3. Optimal: open both = 2.
        let inst = Instance {
            c_d: vec![vec![0.0, 1.0], vec![1.0, 0.0]].into(),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0, 1.0].into(),
            r: vec![10.0, 10.0].into(),
            l: 2.0,
            t_min: 2,
            meta: Default::default(),
        };
        let (sol, cost) = brute_force(&inst).unwrap();
        assert!((cost - 2.0).abs() < 1e-9);
        assert_eq!(sol.assign, vec![Some(0), Some(1)]);
    }

    #[test]
    fn prefers_single_edge_when_global_links_costly() {
        // Same but edge-cloud cost 10: open one edge (10) + remote (2)
        // = 12 vs both open = 20.
        let inst = Instance {
            c_d: vec![vec![0.0, 1.0], vec![1.0, 0.0]].into(),
            c_e: vec![10.0, 10.0],
            lambda: vec![1.0, 1.0].into(),
            r: vec![10.0, 10.0].into(),
            l: 2.0,
            t_min: 2,
            meta: Default::default(),
        };
        let (sol, cost) = brute_force(&inst).unwrap();
        assert!((cost - 12.0).abs() < 1e-9);
        assert_eq!(sol.n_open(), 1);
    }

    #[test]
    fn capacity_forces_spread() {
        // One edge free for both, but capacity 1 forces the second device
        // to the other (expensive) edge.
        let inst = Instance {
            c_d: vec![vec![0.0, 5.0], vec![0.0, 5.0]].into(),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0, 1.0].into(),
            r: vec![1.0, 10.0].into(),
            l: 1.0,
            t_min: 2,
            meta: Default::default(),
        };
        let (sol, cost) = brute_force(&inst).unwrap();
        sol.check_feasible(&inst).unwrap();
        assert!((cost - (1.0 + 1.0 + 5.0)).abs() < 1e-9);
    }

    #[test]
    fn t_min_allows_dropping_expensive_devices() {
        // Device 1 is expensive everywhere; with t_min = 1 it is dropped.
        let inst = Instance {
            c_d: vec![vec![0.0, 0.0], vec![100.0, 100.0]].into(),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0, 1.0].into(),
            r: vec![10.0, 10.0].into(),
            l: 1.0,
            t_min: 1,
            meta: Default::default(),
        };
        let (sol, cost) = brute_force(&inst).unwrap();
        assert_eq!(sol.assign[1], None);
        assert!((cost - 1.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_returns_none() {
        let inst = Instance {
            c_d: vec![vec![0.0], vec![0.0]].into(),
            c_e: vec![1.0],
            lambda: vec![5.0, 5.0].into(),
            r: vec![1.0].into(),
            l: 1.0,
            t_min: 1,
            meta: Default::default(),
        };
        assert!(brute_force(&inst).is_none());
    }

    #[test]
    fn solution_always_feasible() {
        for seed in 0..10 {
            let inst = InstanceBuilder::random(7, 3, seed).t_min(6).build();
            if let Some((sol, cost)) = brute_force(&inst) {
                sol.check_feasible(&inst).unwrap();
                assert!((sol.cost(&inst) - cost).abs() < 1e-9);
            }
        }
    }
}
