//! §VI extension — privacy-constrained HFLOP: "enforcing privacy-related
//! constraints, where a device is allowed to associate only with edge
//! nodes that it trusts ... implemented with modified or additional HFLOP
//! constraints."
//!
//! Implementation: forbidden (device, edge) pairs get a prohibitive
//! communication cost, which drives `x_ij = 0` in any optimal solution;
//! the result is then verified to use no forbidden pair (if the instance
//! is only feasible *through* a forbidden pair, that is reported as
//! infeasibility rather than silently violating trust).

use super::{solve, Solution, SolveError, SolveOptions};
use crate::hflop::Instance;

/// Per-pair trust matrix: `allowed[i][j] = false` forbids assigning
/// device i to edge j.
pub type TrustMatrix = Vec<Vec<bool>>;

/// Cost surrogate for a forbidden link. Large enough to dominate any
/// realistic cost sum, small enough to keep the simplex well-conditioned.
const FORBIDDEN_COST: f64 = 1e7;

/// Build the trust-penalized instance.
pub fn apply_trust(inst: &Instance, allowed: &TrustMatrix) -> anyhow::Result<Instance> {
    anyhow::ensure!(allowed.len() == inst.n(), "trust matrix rows != n");
    let mut out = inst.clone();
    for (i, row) in allowed.iter().enumerate() {
        anyhow::ensure!(row.len() == inst.m(), "trust matrix cols != m");
        for (j, &ok) in row.iter().enumerate() {
            if !ok {
                out.c_d[i][j] = FORBIDDEN_COST;
            }
        }
    }
    Ok(out)
}

/// Solve HFLOP under trust constraints.
pub fn solve_with_trust(
    inst: &Instance,
    allowed: &TrustMatrix,
    opts: &SolveOptions,
) -> Result<Solution, SolveError> {
    let penalized = apply_trust(inst, allowed)
        .map_err(|e| SolveError::Invalid(e.to_string()))?;
    let sol = solve(&penalized, opts)?;
    // Verify: no forbidden pair in the solution.
    for (i, &a) in sol.assignment.assign.iter().enumerate() {
        if let Some(j) = a {
            if !allowed[i][j] {
                return Err(SolveError::Infeasible(format!(
                    "device {i} can only be served by untrusted edge {j}"
                )));
            }
        }
    }
    // Report the true (unpenalized) cost.
    let cost = sol.assignment.cost(inst);
    Ok(Solution { cost, ..sol })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;

    fn all_allowed(n: usize, m: usize) -> TrustMatrix {
        vec![vec![true; m]; n]
    }

    #[test]
    fn no_restrictions_matches_plain_solve() {
        let inst = InstanceBuilder::unit_cost(12, 3, 1).build();
        let plain = solve(&inst, &SolveOptions::exact()).unwrap();
        let trusted = solve_with_trust(&inst, &all_allowed(12, 3), &SolveOptions::exact()).unwrap();
        assert!((plain.cost - trusted.cost).abs() < 1e-9);
    }

    #[test]
    fn forbidden_pair_avoided() {
        let inst = InstanceBuilder::unit_cost(12, 3, 2).build();
        let plain = solve(&inst, &SolveOptions::exact()).unwrap();
        // Forbid every device's currently-assigned edge for device 0.
        let j0 = plain.assignment.assign[0].unwrap();
        let mut allowed = all_allowed(12, 3);
        allowed[0][j0] = false;
        let trusted = solve_with_trust(&inst, &allowed, &SolveOptions::exact()).unwrap();
        assert_ne!(trusted.assignment.assign[0], Some(j0));
        trusted.assignment.check_feasible(&inst).unwrap();
        // Trust can only cost more (or equal).
        assert!(trusted.cost >= plain.cost - 1e-9);
    }

    #[test]
    fn cost_reported_without_penalty() {
        let inst = InstanceBuilder::unit_cost(8, 2, 3).build();
        let mut allowed = all_allowed(8, 2);
        allowed[0][0] = false;
        let trusted = solve_with_trust(&inst, &allowed, &SolveOptions::exact()).unwrap();
        assert!(trusted.cost < 1e6, "penalty leaked into cost: {}", trusted.cost);
    }

    #[test]
    fn infeasible_when_only_untrusted_capacity_remains() {
        // Two edges; device 0 trusts neither -> with T = n this must fail.
        let inst = InstanceBuilder::unit_cost(6, 2, 4).build();
        let mut allowed = all_allowed(6, 2);
        allowed[0][0] = false;
        allowed[0][1] = false;
        let r = solve_with_trust(&inst, &allowed, &SolveOptions::exact());
        assert!(matches!(r, Err(SolveError::Infeasible(_))), "{r:?}");
    }

    #[test]
    fn trust_matrix_shape_validated() {
        let inst = InstanceBuilder::unit_cost(4, 2, 5).build();
        let bad = vec![vec![true; 2]; 3];
        assert!(matches!(
            solve_with_trust(&inst, &bad, &SolveOptions::exact()),
            Err(SolveError::Invalid(_))
        ));
    }
}
