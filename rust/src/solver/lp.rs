//! Dense two-phase primal simplex for linear programs.
//!
//! The offline environment has no LP library, and the exact HFLOP solver
//! (branch & bound, `bb.rs`) needs LP-relaxation lower bounds. This is a
//! textbook two-phase tableau simplex over sparse row input:
//!
//! * minimize `c^T x` subject to rows `a_k^T x {<=,=,>=} b_k`, `x >= 0`;
//! * phase 1 drives artificial variables to zero (infeasibility test),
//!   phase 2 optimizes the true objective;
//! * Dantzig pricing with a Bland's-rule fallback after an iteration
//!   budget to guarantee termination on degenerate problems.
//!
//! Dense is deliberate: B&B nodes solve LPs with a few hundred columns;
//! a dense tableau is simple, cache-friendly and fast at that scale. The
//! tableau is one contiguous [`DenseMatrix`] — pivots are row-slice
//! scale/axpy passes over a single allocation, not a nested-vec pointer
//! chase per row.

use crate::core::{axpy, DenseMatrix};

/// Comparison operator of a constraint row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

/// A sparse constraint row: coefficient list, comparison, rhs.
#[derive(Debug, Clone)]
pub struct Row {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// An LP in "minimize" orientation with non-negative variables.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    pub objective: Vec<f64>,
    pub rows: Vec<Row>,
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

impl Lp {
    pub fn new(n_vars: usize) -> Lp {
        Lp { n_vars, objective: vec![0.0; n_vars], rows: Vec::new() }
    }

    pub fn set_obj(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add_row(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(j, _)| j < self.n_vars));
        self.rows.push(Row { coeffs, cmp, rhs });
    }

    /// Solve with the two-phase simplex.
    pub fn solve(&self) -> LpResult {
        solve_lp(self)
    }
}

const EPS: f64 = 1e-9;

struct SimplexTableau {
    /// tableau[r][c]; last column is RHS; last row is the objective row.
    t: DenseMatrix,
    n_rows: usize,
    n_cols: usize, // total columns incl. slacks/artificials, excl. RHS
    n_struct: usize,
    basis: Vec<usize>,
    artificial_start: usize,
}

impl SimplexTableau {
    fn build(lp: &Lp) -> SimplexTableau {
        let m = lp.rows.len();
        let n = lp.n_vars;

        // Count extra columns: slack/surplus for Le/Ge, artificial for
        // Ge/Eq (and for Le rows with negative rhs after normalization).
        // Normalize every row to rhs >= 0 first.
        let mut rows: Vec<Row> = lp.rows.clone();
        for r in rows.iter_mut() {
            if r.rhs < 0.0 {
                r.rhs = -r.rhs;
                for c in r.coeffs.iter_mut() {
                    c.1 = -c.1;
                }
                r.cmp = match r.cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
            }
        }

        let n_slack = rows.iter().filter(|r| r.cmp != Cmp::Eq).count();
        let n_art = rows.iter().filter(|r| r.cmp != Cmp::Le).count();
        let n_cols = n + n_slack + n_art;
        let mut t = DenseMatrix::zeros(m + 1, n_cols + 1);
        let mut basis = vec![0usize; m];

        let mut slack_idx = n;
        let mut art_idx = n + n_slack;
        let artificial_start = n + n_slack;

        for (k, row) in rows.iter().enumerate() {
            for &(j, v) in &row.coeffs {
                t[k][j] += v;
            }
            t[k][n_cols] = row.rhs;
            match row.cmp {
                Cmp::Le => {
                    t[k][slack_idx] = 1.0;
                    basis[k] = slack_idx;
                    slack_idx += 1;
                }
                Cmp::Ge => {
                    t[k][slack_idx] = -1.0;
                    slack_idx += 1;
                    t[k][art_idx] = 1.0;
                    basis[k] = art_idx;
                    art_idx += 1;
                }
                Cmp::Eq => {
                    t[k][art_idx] = 1.0;
                    basis[k] = art_idx;
                    art_idx += 1;
                }
            }
        }

        let mut s = SimplexTableau {
            t,
            n_rows: m,
            n_cols,
            n_struct: n,
            basis,
            artificial_start,
        };
        // Phase-1 objective: minimize sum of artificials. Express as the
        // objective row = sum of rows whose basic var is artificial.
        for k in 0..m {
            if s.basis[k] >= artificial_start {
                let (obj, src) = s.t.row_pair_mut(m, k);
                axpy(obj, src, 1.0);
            }
        }
        // Zero out artificial columns in the objective row (they are basic
        // with coefficient 1 each; the row sum already includes them, so
        // subtract their identity contribution).
        for c in artificial_start..n_cols {
            s.t[m][c] -= 1.0;
        }
        s
    }

    /// Pivot column choice: Dantzig (most positive reduced cost in the
    /// max-oriented row form we keep) with Bland fallback.
    fn choose_col(&self, bland: bool, allow: impl Fn(usize) -> bool) -> Option<usize> {
        let obj = self.t.row(self.n_rows);
        if bland {
            (0..self.n_cols).find(|&c| allow(c) && obj[c] > EPS)
        } else {
            let mut best = None;
            let mut best_v = EPS;
            for c in 0..self.n_cols {
                if allow(c) && obj[c] > best_v {
                    best_v = obj[c];
                    best = Some(c);
                }
            }
            best
        }
    }

    fn choose_row(&self, col: usize) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for r in 0..self.n_rows {
            let a = self.t[r][col];
            if a > EPS {
                let ratio = self.t[r][self.n_cols] / a;
                match best {
                    None => best = Some((r, ratio)),
                    Some((br, bratio)) => {
                        // Tie-break on smaller basis index (Bland-ish).
                        if ratio < bratio - EPS
                            || (ratio < bratio + EPS && self.basis[r] < self.basis[br])
                        {
                            best = Some((r, ratio));
                        }
                    }
                }
            }
        }
        best.map(|(r, _)| r)
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let piv = self.t[row][col];
        debug_assert!(piv.abs() > EPS);
        self.t.scale_row(row, 1.0 / piv);
        for r in 0..=self.n_rows {
            if r != row {
                let (dst, src) = self.t.row_pair_mut(r, row);
                let f = dst[col];
                if f.abs() > EPS {
                    axpy(dst, src, -f);
                }
            }
        }
        self.basis[row] = col;
    }

    /// Run simplex iterations on the current objective row.
    /// Returns false if unbounded.
    fn iterate(&mut self, allow: impl Fn(usize) -> bool + Copy) -> bool {
        let mut iters = 0usize;
        let bland_after = 50 * (self.n_rows + self.n_cols);
        loop {
            let bland = iters > bland_after;
            let Some(col) = self.choose_col(bland, allow) else {
                return true; // optimal
            };
            let Some(row) = self.choose_row(col) else {
                return false; // unbounded
            };
            self.pivot(row, col);
            iters += 1;
            if iters > 200 * (self.n_rows + self.n_cols) + 10_000 {
                // Termination safeguard; with Bland active this should be
                // unreachable, but never hang the caller.
                return true;
            }
        }
    }

}

/// Two-phase simplex driver (the tableau holds structure; the original
/// objective lives in `lp`).
pub fn solve_lp(lp: &Lp) -> LpResult {
    let mut s = SimplexTableau::build(lp);
    let m = s.n_rows;
    let has_artificials = s.artificial_start < s.n_cols;

    if has_artificials {
        if !s.iterate(|_| true) {
            return LpResult::Infeasible; // phase 1 is bounded below by 0
        }
        if s.t[m][s.n_cols] > 1e-6 {
            return LpResult::Infeasible;
        }
        for r in 0..m {
            if s.basis[r] >= s.artificial_start {
                if let Some(col) = (0..s.artificial_start).find(|&c| s.t[r][c].abs() > 1e-7) {
                    s.pivot(r, col);
                }
            }
        }
    }

    // Phase 2 objective row (max `-c^T x` orientation).
    s.t.row_mut(m).fill(0.0);
    for (j, &cost) in lp.objective.iter().enumerate() {
        s.t[m][j] = -cost;
    }
    // Eliminate basic structural columns from the objective row.
    for r in 0..m {
        let b = s.basis[r];
        let v = s.t[m][b];
        if v.abs() > EPS {
            let (obj, src) = s.t.row_pair_mut(m, r);
            axpy(obj, src, -v);
        }
    }

    let art_start = s.artificial_start;
    if !s.iterate(move |c| c < art_start) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0; s.n_struct];
    for r in 0..m {
        let b = s.basis[r];
        if b < s.n_struct {
            x[b] = s.t[r][s.n_cols];
        }
    }
    let obj = x.iter().zip(&lp.objective).map(|(&v, &c)| v * c).sum();
    LpResult::Optimal { x, obj }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(res: &LpResult, want_obj: f64, tol: f64) -> Vec<f64> {
        match res {
            LpResult::Optimal { x, obj } => {
                assert!((obj - want_obj).abs() < tol, "obj {obj} want {want_obj}");
                x.clone()
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn simple_min_le() {
        // min -x - 2y  s.t. x + y <= 4, x <= 2, y <= 3   => x=1? Let's see:
        // best is y=3, x=1 -> obj = -7.
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -2.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 4.0);
        lp.add_row(vec![(0, 1.0)], Cmp::Le, 2.0);
        lp.add_row(vec![(1, 1.0)], Cmp::Le, 3.0);
        let x = assert_opt(&solve_lp(&lp), -7.0, 1e-7);
        assert!((x[0] - 1.0).abs() < 1e-7);
        assert!((x[1] - 3.0).abs() < 1e-7);
    }

    #[test]
    fn equality_constraint() {
        // min x + y  s.t. x + y = 5, x >= 0, y >= 0 -> obj 5.
        let mut lp = Lp::new(2);
        lp.set_obj(0, 1.0);
        lp.set_obj(1, 1.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0);
        assert_opt(&solve_lp(&lp), 5.0, 1e-7);
    }

    #[test]
    fn ge_constraints_transportation() {
        // min 2a + 3b s.t. a + b >= 10, a <= 6 -> a=6,b=4 -> 24.
        let mut lp = Lp::new(2);
        lp.set_obj(0, 2.0);
        lp.set_obj(1, 3.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Cmp::Ge, 10.0);
        lp.add_row(vec![(0, 1.0)], Cmp::Le, 6.0);
        let x = assert_opt(&solve_lp(&lp), 24.0, 1e-7);
        assert!((x[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2.
        let mut lp = Lp::new(1);
        lp.set_obj(0, 1.0);
        lp.add_row(vec![(0, 1.0)], Cmp::Le, 1.0);
        lp.add_row(vec![(0, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve_lp(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, x >= 0, no upper bound.
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0);
        assert_eq!(solve_lp(&lp), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // -x <= -3  <=>  x >= 3; min x -> 3.
        let mut lp = Lp::new(1);
        lp.set_obj(0, 1.0);
        lp.add_row(vec![(0, -1.0)], Cmp::Le, -3.0);
        assert_opt(&solve_lp(&lp), 3.0, 1e-7);
    }

    #[test]
    fn degenerate_does_not_hang() {
        // Classic degenerate LP.
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -1.0);
        lp.add_row(vec![(0, 1.0)], Cmp::Le, 1.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 1.0);
        lp.add_row(vec![(1, 1.0)], Cmp::Le, 1.0);
        assert_opt(&solve_lp(&lp), -1.0, 1e-7);
    }

    #[test]
    fn zero_objective_feasibility_only() {
        let mut lp = Lp::new(2);
        lp.add_row(vec![(0, 1.0), (1, 2.0)], Cmp::Eq, 4.0);
        match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                assert!(obj.abs() < 1e-9);
                assert!((x[0] + 2.0 * x[1] - 4.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn facility_location_relaxation_shape() {
        // Tiny relaxed facility location: 2 devices, 2 sites.
        // min x00*0 + x01*1 + x10*1 + x11*0 + 5(y0 + y1)
        // s.t. sum_j x_ij = 1; x_ij <= y_j; y_j <= 1.
        // Optimal: open both (cost 10) with free assignments -> 10, or
        // open one (cost 5) + one remote assignment (1) -> 6. LP can keep
        // y fractional: x only needs y >= x, so y0=1,y1=0 -> 5+1=6;
        // fractional y: y0=y1=0.5 -> x00<=0.5... must sum 1 per device, so
        // x00=0.5,x01=0.5 etc. cost = 0.5 + 0.5 + 5 = 6? same. obj 6.
        let mut lp = Lp::new(6); // x00,x01,x10,x11,y0,y1
        let (x00, x01, x10, x11, y0, y1) = (0, 1, 2, 3, 4, 5);
        lp.set_obj(x01, 1.0);
        lp.set_obj(x10, 1.0);
        lp.set_obj(y0, 5.0);
        lp.set_obj(y1, 5.0);
        lp.add_row(vec![(x00, 1.0), (x01, 1.0)], Cmp::Eq, 1.0);
        lp.add_row(vec![(x10, 1.0), (x11, 1.0)], Cmp::Eq, 1.0);
        for (x, y) in [(x00, y0), (x01, y1), (x10, y0), (x11, y1)] {
            lp.add_row(vec![(x, 1.0), (y, -1.0)], Cmp::Le, 0.0);
        }
        lp.add_row(vec![(y0, 1.0)], Cmp::Le, 1.0);
        lp.add_row(vec![(y1, 1.0)], Cmp::Le, 1.0);
        assert_opt(&solve_lp(&lp), 6.0, 1e-6);
    }

    #[test]
    fn larger_random_lp_consistency() {
        // A randomly generated feasible LP: check optimality by weak
        // duality proxy — the optimum must not exceed any feasible point
        // we construct.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let n = 20;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_obj(j, rng.uniform(0.1, 2.0));
        }
        // sum x_j >= 5, x_j <= 1 each.
        lp.add_row((0..n).map(|j| (j, 1.0)).collect(), Cmp::Ge, 5.0);
        for j in 0..n {
            lp.add_row(vec![(j, 1.0)], Cmp::Le, 1.0);
        }
        match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                // Feasibility of returned point.
                let s: f64 = x.iter().sum();
                assert!(s >= 5.0 - 1e-6);
                assert!(x.iter().all(|&v| (-1e-9..=1.0 + 1e-6).contains(&v)));
                // The greedy "5 cheapest vars at 1" point is feasible;
                // optimum must be <= its cost.
                let mut costs = lp.objective.clone();
                costs.sort_by(f64::total_cmp);
                let greedy: f64 = costs[..5].iter().sum();
                assert!(obj <= greedy + 1e-6);
                assert!((obj - greedy).abs() < 1e-6); // actually equal here
            }
            other => panic!("{other:?}"),
        }
    }
}
