//! Sharded, region-parallel HFLOP solves for candidate-sparse instances.
//!
//! One global solve over a million devices is intractable for the dense
//! solver stack, but the *geography* of the problem decomposes it: a
//! device is only competitively served by nearby edges. The sharded path
//! exploits that in four deterministic stages:
//!
//! 1. **Partition** — weighted k-means (`topology::kmeans_weighted`,
//!    weights = λ) over a stride-sample of device positions yields K
//!    region centroids; every edge joins its nearest centroid and every
//!    device joins the region of its nearest candidate edge. The global
//!    `t_min` is split across regions by device count (largest-remainder
//!    rounding).
//! 2. **Regional solves** — each region builds a *dense* sub-instance
//!    (small: Σ n_k·m_k ≈ n·m/K) and solves it with the existing
//!    exact/heuristic stack, plus seeded random-restart starts. Regions
//!    run on `util::pool` workers; each region's RNG stream derives from
//!    `mix_seed(root_seed, [SALT_REGION, k])`, so the outcome is
//!    bit-identical at any worker count.
//! 3. **Rescue** — if regional capacity shortfalls left the global
//!    participation constraint unmet, unassigned devices (cheapest λ
//!    first) are placed on their best candidate edge anywhere — in
//!    region or halo — opening edges as needed.
//! 4. **Repair** — bounded sweeps re-associate devices whose *halo*
//!    candidate (an open out-of-region edge with residual capacity)
//!    strictly beats their current assignment. Moves never open or close
//!    edges and respect capacity residuals, so feasibility is invariant.
//!
//! [`aggregated_lp_bound`] provides an O(n·k + m log m) lower bound on
//! the optimum (no LP tableau, no dense matrix), used by `bench_solver`
//! to report the heuristic gap at scale.

use crate::core::DenseMatrix;
use crate::hflop::sparse::{Proj, SparseInstance};
use crate::hflop::{Instance, InstanceMeta};
use crate::solver::{
    complete_assignment, refine_assignment, solve, Assignment, Mode, SolveError, SolveOptions,
    Solution,
};
use crate::topology::kmeans_weighted;
use crate::util::pool;
use crate::util::rng::{mix_seed, Rng};
use crate::util::time_it;

/// Seed-derivation salts: region partitioning and per-region solve
/// streams must be unrelated even for equal indices.
const SALT_KMEANS: u64 = 0x6b6d_6561_6e73; // "kmeans"
const SALT_REGION: u64 = 0x7265_6769_6f6e; // "region"

/// k-means runs on at most this many sampled devices (stride sampling —
/// deterministic, and plenty for metro-scale centroid placement).
const KMEANS_SAMPLE_MAX: usize = 4096;
const KMEANS_ITERS: usize = 40;

/// Sharding knobs, carried inside [`SolveOptions`].
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Region count K; 0 = auto (`m/8`, clamped to `[1, 256]`).
    pub regions: usize,
    /// Root seed; every per-region stream derives from it via
    /// `mix_seed`, so one u64 reproduces the entire solve.
    pub root_seed: u64,
    /// Worker threads for the region fan-out; 0 = available parallelism.
    /// Changes wall time only, never the result.
    pub workers: usize,
    /// Seeded random-restart starts per region, tried in addition to the
    /// deterministic base solve (best of all wins; ties keep the
    /// earliest).
    pub restarts: usize,
    /// Cross-region repair sweeps over halo candidates.
    pub repair_sweeps: usize,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions { regions: 0, root_seed: 7, workers: 0, restarts: 1, repair_sweeps: 2 }
    }
}

/// Diagnostics from a sharded solve.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Non-empty regions actually solved.
    pub regions: usize,
    /// Device count of the largest region (shard balance indicator).
    pub largest_region_devices: usize,
    /// Σ over regions of participation the region could not serve
    /// locally (capacity-reduced t_min); made up by the rescue pass.
    pub region_t_min_shortfall: usize,
    /// Devices assigned by the global rescue pass.
    pub rescued: usize,
    /// Improving halo re-associations applied by the repair pass.
    pub repair_moves: usize,
}

/// A sharded solve result: the solution plus shard diagnostics.
#[derive(Debug, Clone)]
pub struct ShardedOutcome {
    pub solution: Solution,
    pub stats: ShardStats,
}

/// Solve a candidate-sparse instance with the region-parallel pipeline.
/// Bit-identical for a fixed `opts.shard.root_seed` at any worker count.
pub fn solve_sharded(
    sp: &SparseInstance,
    opts: &SolveOptions,
) -> Result<ShardedOutcome, SolveError> {
    let (res, wall_s) = time_it(|| shard_inner(sp, opts));
    let (assignment, cost, stats) = res?;
    Ok(ShardedOutcome {
        solution: Solution { assignment, cost, proven_optimal: false, nodes: 0, wall_s },
        stats,
    })
}

fn shard_inner(
    sp: &SparseInstance,
    opts: &SolveOptions,
) -> Result<(Assignment, f64, ShardStats), SolveError> {
    sp.validate().map_err(|e| SolveError::Invalid(e.to_string()))?;
    if !sparse_capacity_feasible(sp) {
        return Err(SolveError::Infeasible("aggregate capacity below t_min demand".into()));
    }
    let (n, m) = (sp.n(), sp.m());
    let so = &opts.shard;
    let pr = sp.proj();

    // --- 1. regions: weighted k-means over a device sample ---------------
    let k_target = if so.regions > 0 { so.regions } else { (m / 8).clamp(1, 256) }.min(m);
    let stride = n.div_ceil(KMEANS_SAMPLE_MAX).max(1);
    let sample_idx: Vec<usize> = (0..n).step_by(stride).collect();
    let sample_pts: Vec<_> = sample_idx.iter().map(|&i| sp.device_pos[i]).collect();
    let sample_w: Vec<f64> = sample_idx.iter().map(|&i| sp.lambda[i]).collect();
    let mut km_rng = Rng::new(mix_seed(so.root_seed, &[SALT_KMEANS]));
    let km = kmeans_weighted(&sample_pts, Some(&sample_w), k_target, KMEANS_ITERS, &mut km_rng);

    // Edges to nearest centroid; drop centroids that attracted no edge
    // (region ids are compacted in first-edge order — deterministic).
    let raw_of_edge: Vec<usize> = sp
        .edge_pos
        .iter()
        .map(|&e| {
            (0..km.centroids.len())
                .min_by(|&a, &b| {
                    pr.dist_km(e, km.centroids[a]).total_cmp(&pr.dist_km(e, km.centroids[b]))
                })
                .expect("at least one centroid")
        })
        .collect();
    let mut remap = vec![usize::MAX; km.centroids.len()];
    let mut n_regions = 0usize;
    for &c in &raw_of_edge {
        if remap[c] == usize::MAX {
            remap[c] = n_regions;
            n_regions += 1;
        }
    }
    let mut edges_of: Vec<Vec<usize>> = vec![Vec::new(); n_regions];
    for (j, &c) in raw_of_edge.iter().enumerate() {
        edges_of[remap[c]].push(j);
    }
    // A device belongs to the region of its nearest candidate edge, so it
    // always has at least one in-region candidate.
    let mut devs_of: Vec<Vec<usize>> = vec![Vec::new(); n_regions];
    for i in 0..n {
        let nearest = sp.cand_edges[i * sp.cand_k] as usize;
        devs_of[remap[raw_of_edge[nearest]]].push(i);
    }
    let tmins = split_t_min(sp.t_min, &devs_of);

    // --- 2. regional solves on the worker pool ---------------------------
    let workers = if so.workers == 0 { pool::default_workers() } else { so.workers };
    let results: Vec<RegionResult> = pool::scoped_map(workers, n_regions, |k| {
        let seed = mix_seed(so.root_seed, &[SALT_REGION, k as u64]);
        solve_region(sp, &pr, &devs_of[k], &edges_of[k], tmins[k], opts, seed)
    });

    // --- merge to global state -------------------------------------------
    let mut assign: Vec<Option<usize>> = vec![None; n];
    let mut open = vec![false; m];
    let mut stats = ShardStats { regions: n_regions, ..Default::default() };
    for (k, res) in results.iter().enumerate() {
        stats.largest_region_devices = stats.largest_region_devices.max(devs_of[k].len());
        stats.region_t_min_shortfall += res.shortfall;
        for (lj, &o) in res.open.iter().enumerate() {
            if o {
                open[edges_of[k][lj]] = true;
            }
        }
        for (li, &a) in res.assign.iter().enumerate() {
            if let Some(lj) = a {
                assign[devs_of[k][li]] = Some(edges_of[k][lj]);
            }
        }
    }
    let mut residual: Vec<f64> = sp.r.to_vec();
    let mut served = 0usize;
    for (i, &a) in assign.iter().enumerate() {
        if let Some(j) = a {
            residual[j] -= sp.lambda[i];
            served += 1;
        }
    }

    // --- 3. rescue: meet global t_min over any candidate edge ------------
    if served < sp.t_min {
        let mut unassigned: Vec<usize> = (0..n).filter(|&i| assign[i].is_none()).collect();
        unassigned.sort_by(|&a, &b| sp.lambda[a].total_cmp(&sp.lambda[b]).then(a.cmp(&b)));
        for i in unassigned {
            if served >= sp.t_min {
                break;
            }
            let lam = sp.lambda[i];
            let mut best: Option<(f64, usize)> = None;
            for (j, c) in sp.candidates(i) {
                if residual[j] + 1e-9 < lam {
                    continue;
                }
                let eff = sp.l * c + if open[j] { 0.0 } else { sp.c_e[j] };
                let better = match best {
                    None => true,
                    Some((bc, bj)) => eff.total_cmp(&bc).then(j.cmp(&bj)).is_lt(),
                };
                if better {
                    best = Some((eff, j));
                }
            }
            if let Some((_, j)) = best {
                open[j] = true;
                assign[i] = Some(j);
                residual[j] -= lam;
                served += 1;
                stats.rescued += 1;
            }
        }
        if served < sp.t_min {
            return Err(SolveError::Infeasible(format!(
                "sharded solve served {served} devices < t_min {}",
                sp.t_min
            )));
        }
    }

    // --- 4. repair: improving halo moves, feasibility-invariant ----------
    for _ in 0..so.repair_sweeps {
        let mut moved = false;
        for i in 0..n {
            let Some(cur) = assign[i] else { continue };
            let lam = sp.lambda[i];
            let cur_cost = sp.pair_cost(&pr, i, cur);
            let mut best: Option<(f64, usize)> = None;
            for (j, c) in sp.candidates(i) {
                if j == cur || !open[j] || residual[j] + 1e-9 < lam || c >= cur_cost - 1e-12 {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bc, bj)) => c.total_cmp(&bc).then(j.cmp(&bj)).is_lt(),
                };
                if better {
                    best = Some((c, j));
                }
            }
            if let Some((_, j)) = best {
                residual[cur] += lam;
                residual[j] -= lam;
                assign[i] = Some(j);
                stats.repair_moves += 1;
                moved = true;
            }
        }
        if !moved {
            break;
        }
    }

    // Close edges the repair pass emptied (constraint 3; pure cost win).
    let mut used = vec![false; m];
    for &a in &assign {
        if let Some(j) = a {
            used[j] = true;
        }
    }
    for (o, &u) in open.iter_mut().zip(&used) {
        if !u {
            *o = false;
        }
    }

    // --- final cost, summed in fixed index order (bit-stable) ------------
    let mut local = 0.0;
    for (i, &a) in assign.iter().enumerate() {
        if let Some(j) = a {
            local += sp.pair_cost(&pr, i, j);
        }
    }
    let mut opening = 0.0;
    for (j, &o) in open.iter().enumerate() {
        if o {
            opening += sp.c_e[j];
        }
    }
    let cost = local * sp.l + opening;
    Ok((Assignment { assign, open }, cost, stats))
}

struct RegionResult {
    /// Local device index → local edge index.
    assign: Vec<Option<usize>>,
    open: Vec<bool>,
    /// Participation this region was asked for but could not serve.
    shortfall: usize,
}

/// Solve one region as a dense sub-instance: deterministic base solve,
/// then seeded random-restart starts; best cost wins (ties keep the
/// earliest candidate, so the outcome is a pure function of the inputs).
fn solve_region(
    sp: &SparseInstance,
    pr: &Proj,
    devs: &[usize],
    edges: &[usize],
    t_min_k: usize,
    opts: &SolveOptions,
    region_seed: u64,
) -> RegionResult {
    let (nk, mk) = (devs.len(), edges.len());
    if nk == 0 || mk == 0 {
        return RegionResult { assign: vec![None; nk], open: vec![false; mk], shortfall: t_min_k };
    }
    // Reduce the regional participation target to what regional capacity
    // can hold; the global rescue pass makes up the difference over halo
    // edges.
    let total_r: f64 = edges.iter().map(|&j| sp.r[j]).sum();
    let t_eff = if total_r.is_infinite() {
        t_min_k
    } else {
        let mut lam: Vec<f64> = devs.iter().map(|&i| sp.lambda[i]).collect();
        lam.sort_by(f64::total_cmp);
        let mut acc = 0.0;
        let mut fit = 0usize;
        for v in lam {
            if acc + v <= total_r + 1e-9 {
                acc += v;
                fit += 1;
            } else {
                break;
            }
        }
        t_min_k.min(fit)
    };
    let sub = Instance {
        c_d: DenseMatrix::from_fn(nk, mk, |a, b| sp.pair_cost(pr, devs[a], edges[b])),
        c_e: edges.iter().map(|&j| sp.c_e[j]).collect(),
        lambda: devs.iter().map(|&i| sp.lambda[i]).collect(),
        r: edges.iter().map(|&j| sp.r[j]).collect(),
        l: sp.l,
        t_min: t_eff,
        meta: InstanceMeta::prevalidated(),
    };
    let mut sub_opts = opts.clone();
    sub_opts.mode = Mode::Auto;
    let mut best: Option<(Assignment, f64)> = None;
    if let Ok(sol) = solve(&sub, &sub_opts) {
        best = Some((sol.assignment, sol.cost));
    }
    for t in 0..opts.shard.restarts {
        let mut rng = Rng::new(mix_seed(region_seed, &[t as u64]));
        let mut mask = vec![false; mk];
        for o in mask.iter_mut() {
            *o = rng.chance(0.5);
        }
        if !mask.iter().any(|&o| o) {
            mask[rng.below(mk)] = true;
        }
        if let Some(asg) = complete_assignment(&sub, &mask) {
            let asg = refine_assignment(&sub, &asg);
            let cost = asg.cost(&sub);
            let better = match &best {
                None => true,
                Some((_, bc)) => cost < bc - 1e-12,
            };
            if better {
                best = Some((asg, cost));
            }
        }
    }
    match best {
        Some((asg, _)) => {
            let assigned = asg.assign.iter().filter(|a| a.is_some()).count();
            RegionResult {
                assign: asg.assign,
                open: asg.open,
                shortfall: t_min_k.saturating_sub(assigned),
            }
        }
        None => RegionResult { assign: vec![None; nk], open: vec![false; mk], shortfall: t_min_k },
    }
}

/// Split the global `t_min` across regions proportionally to device
/// counts (largest-remainder rounding, capped at each region's size).
/// Sums to exactly `t_min` whenever `t_min ≤ Σ region sizes`.
fn split_t_min(t_min: usize, devs_of: &[Vec<usize>]) -> Vec<usize> {
    let n_total: usize = devs_of.iter().map(|d| d.len()).sum();
    if n_total == 0 || t_min == 0 {
        return vec![0; devs_of.len()];
    }
    let mut base = Vec::with_capacity(devs_of.len());
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(devs_of.len());
    let mut assigned = 0usize;
    for (k, devs) in devs_of.iter().enumerate() {
        let quota = t_min as f64 * devs.len() as f64 / n_total as f64;
        let b = (quota.floor().max(0.0) as usize).min(devs.len());
        base.push(b);
        assigned += b;
        fracs.push((quota - b as f64, k));
    }
    // Remainder by largest fractional part, region index as tiebreak;
    // keep cycling while capacity remains (floors sum to ≤ t_min ≤ n).
    fracs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut rem = t_min.saturating_sub(assigned);
    while rem > 0 {
        let mut progressed = false;
        for &(_, k) in &fracs {
            if rem == 0 {
                break;
            }
            if base[k] < devs_of[k].len() {
                base[k] += 1;
                rem -= 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    base
}

/// Necessary capacity check, mirroring `Instance::capacity_feasible` on
/// the sparse representation (greedy-pack smallest λ into Σr).
fn sparse_capacity_feasible(sp: &SparseInstance) -> bool {
    let total: f64 = sp.r.iter().sum();
    if total.is_infinite() {
        return true;
    }
    if sp.lambda.iter().sum::<f64>() <= total + 1e-9 {
        return sp.lambda.len() >= sp.t_min;
    }
    let mut lam = sp.lambda.to_vec();
    lam.sort_by(f64::total_cmp);
    let mut acc = 0.0;
    let mut fit = 0usize;
    for v in lam {
        if acc + v <= total + 1e-9 {
            acc += v;
            fit += 1;
        } else {
            break;
        }
    }
    fit >= sp.t_min
}

/// Lower bound on the HFLOP optimum from the aggregated-LP decomposition,
/// in O(n log n + m log m) with no dense matrix:
///
/// * assignment part — any feasible solution assigns ≥ t_min devices, and
///   each assigned device pays at least its row-minimum cost (the first
///   candidate, lists being cost-ascending), so `l · Σ` of the t_min
///   smallest row minima is a valid floor;
/// * opening part — summing capacity constraint (4) over edges gives
///   `Σ r_j y_j ≥ Σ assigned λ ≥ Λ`, where Λ is the sum of the t_min
///   smallest λ; the fractional knapsack `min Σ c_e_j y_j` under that
///   aggregate constraint (greedy by c_e/r ratio) lower-bounds the edge
///   opening cost. (Λ is relaxed by 1e-6 to stay below the solvers'
///   per-edge capacity tolerance.)
pub fn aggregated_lp_bound(sp: &SparseInstance) -> f64 {
    let t = sp.t_min;
    if t == 0 {
        return 0.0;
    }
    let mut cmin: Vec<f64> = (0..sp.n()).map(|i| sp.cand_costs[i * sp.cand_k]).collect();
    cmin.sort_by(f64::total_cmp);
    let assign_part: f64 = sp.l * cmin[..t].iter().sum::<f64>();

    let mut lam = sp.lambda.to_vec();
    lam.sort_by(f64::total_cmp);
    let needed = lam[..t].iter().sum::<f64>() - 1e-6;
    let mut opening = 0.0;
    if needed > 0.0 {
        let mut order: Vec<usize> = (0..sp.m()).collect();
        order.sort_by(|&a, &b| {
            cost_per_capacity(sp.c_e[a], sp.r[a])
                .total_cmp(&cost_per_capacity(sp.c_e[b], sp.r[b]))
                .then(a.cmp(&b))
        });
        let mut remaining = needed;
        for &j in &order {
            if remaining <= 0.0 {
                break;
            }
            let rj = sp.r[j];
            if rj <= 0.0 {
                continue;
            }
            if rj.is_infinite() {
                // y_j → 0⁺ already satisfies the aggregate constraint;
                // the LP infimum adds nothing here.
                remaining = 0.0;
                break;
            }
            let y = (remaining / rj).min(1.0);
            opening += y * sp.c_e[j];
            remaining -= y * rj;
        }
        // If capacity ran out the instance has no feasible solution
        // either, so the partial sum is still a valid bound.
    }
    assign_part + opening
}

fn cost_per_capacity(c: f64, r: f64) -> f64 {
    if r <= 0.0 {
        f64::INFINITY
    } else if r.is_infinite() {
        0.0
    } else {
        c / r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sharded_opts(root_seed: u64, workers: usize) -> SolveOptions {
        let mut opts = SolveOptions::sharded();
        opts.shard.root_seed = root_seed;
        opts.shard.workers = workers;
        opts
    }

    #[test]
    fn sharded_solution_is_feasible_on_dense_equivalent() {
        let sp = SparseInstance::clustered(400, 8, 3, 4);
        let out = solve_sharded(&sp, &sharded_opts(11, 2)).unwrap();
        let dense = sp.to_dense();
        out.solution.assignment.check_feasible(&dense).unwrap();
        let dense_cost = out.solution.assignment.cost(&dense);
        assert!((out.solution.cost - dense_cost).abs() < 1e-9);
        assert!(out.stats.regions >= 1);
    }

    #[test]
    fn sharded_identical_across_worker_counts() {
        let sp = SparseInstance::clustered(500, 16, 9, 4);
        let base = solve_sharded(&sp, &sharded_opts(5, 1)).unwrap();
        for workers in [2, 8] {
            let out = solve_sharded(&sp, &sharded_opts(5, workers)).unwrap();
            assert_eq!(out.solution.assignment.assign, base.solution.assignment.assign);
            assert_eq!(out.solution.assignment.open, base.solution.assignment.open);
            assert_eq!(out.solution.cost.to_bits(), base.solution.cost.to_bits());
        }
    }

    #[test]
    fn bound_is_below_cost_and_exact_optimum() {
        // Small enough for the exact solver via the dense equivalent.
        let sp = SparseInstance::clustered(14, 3, 21, 2);
        let bound = aggregated_lp_bound(&sp);
        let dense = sp.to_dense();
        let exact = solve(&dense, &SolveOptions::exact()).unwrap();
        assert!(exact.proven_optimal);
        assert!(bound <= exact.cost + 1e-9, "bound {bound} > optimum {}", exact.cost);
        let sharded = solve_sharded(&sp, &sharded_opts(3, 1)).unwrap();
        assert!(sharded.solution.cost + 1e-9 >= bound);
        assert!(sharded.solution.cost + 1e-9 >= exact.cost);
    }

    #[test]
    fn split_t_min_sums_and_respects_sizes() {
        let devs_of: Vec<Vec<usize>> = vec![(0..5).collect(), (5..8).collect(), (8..20).collect()];
        for t in 0..=20 {
            let split = split_t_min(t, &devs_of);
            assert_eq!(split.iter().sum::<usize>(), t, "t={t}");
            for (k, s) in split.iter().enumerate() {
                assert!(*s <= devs_of[k].len());
            }
        }
    }

    #[test]
    fn infeasible_capacity_is_reported() {
        let mut sp = SparseInstance::clustered(50, 4, 2, 2);
        for r in sp.r.iter_mut() {
            *r = 0.01;
        }
        assert!(matches!(
            solve_sharded(&sp, &sharded_opts(1, 1)),
            Err(SolveError::Infeasible(_))
        ));
    }

    #[test]
    fn uncapacitated_sparse_solves() {
        let mut sp = SparseInstance::clustered(120, 6, 8, 3);
        for r in sp.r.iter_mut() {
            *r = f64::INFINITY;
        }
        let out = solve_sharded(&sp, &sharded_opts(2, 2)).unwrap();
        let dense = sp.to_dense();
        out.solution.assignment.check_feasible(&dense).unwrap();
    }
}
