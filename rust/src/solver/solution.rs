//! Solution representation, feasibility checking, cost evaluation, the
//! capacity-aware assignment-completion heuristic shared by the greedy,
//! local-search and branch & bound incumbent rounding — and the
//! [`IncrementalEvaluator`], which maintains per-edge residual capacity
//! and a running objective so reassign/swap moves are scored in O(1)
//! delta instead of a full [`Assignment::cost`] recompute.

use crate::hflop::Instance;

/// A (candidate) HFLOP solution: device→edge assignment + open aggregators.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// `assign[i] = Some(j)` if device i is served by edge j (x_ij = 1).
    pub assign: Vec<Option<usize>>,
    /// `open[j] = true` if an aggregator is placed at edge j (y_j = 1).
    pub open: Vec<bool>,
}

impl Assignment {
    pub fn empty(n: usize, m: usize) -> Assignment {
        Assignment { assign: vec![None; n], open: vec![false; m] }
    }

    pub fn n_assigned(&self) -> usize {
        self.assign.iter().filter(|a| a.is_some()).count()
    }

    pub fn n_open(&self) -> usize {
        self.open.iter().filter(|&&o| o).count()
    }

    /// Devices served by edge `j`.
    pub fn devices_of(&self, j: usize) -> Vec<usize> {
        self.assign
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| (a == Some(j)).then_some(i))
            .collect()
    }

    /// Objective value (Eq. 1): `Σ x_ij c_d[i][j] l + Σ y_j c_e[j]`.
    pub fn cost(&self, inst: &Instance) -> f64 {
        let local: f64 = self
            .assign
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| a.map(|j| inst.c_d[i][j]))
            .sum();
        let global: f64 = self
            .open
            .iter()
            .enumerate()
            .filter_map(|(j, &o)| o.then_some(inst.c_e[j]))
            .sum();
        local * inst.l + global
    }

    /// Load (Σ λ_i of assigned devices) per edge.
    pub fn loads(&self, inst: &Instance) -> Vec<f64> {
        let mut loads = vec![0.0; inst.m()];
        for (i, &a) in self.assign.iter().enumerate() {
            if let Some(j) = a {
                loads[j] += inst.lambda[i];
            }
        }
        loads
    }

    /// Check all HFLOP constraints (2)–(6). Returns a violation message.
    pub fn check_feasible(&self, inst: &Instance) -> Result<(), String> {
        let (n, m) = (inst.n(), inst.m());
        if self.assign.len() != n || self.open.len() != m {
            return Err("dimension mismatch".into());
        }
        // (2) x_ij <= y_j: assigned edge must be open.
        for (i, &a) in self.assign.iter().enumerate() {
            if let Some(j) = a {
                if j >= m {
                    return Err(format!("device {i} assigned to invalid edge {j}"));
                }
                if !self.open[j] {
                    return Err(format!("device {i} assigned to closed edge {j}"));
                }
            }
        }
        // (3) y_j <= sum_i x_ij: no empty open aggregator.
        for j in 0..m {
            if self.open[j] && !self.assign.iter().any(|&a| a == Some(j)) {
                return Err(format!("edge {j} open but serves no device"));
            }
        }
        // (4) capacity.
        for (j, load) in self.loads(inst).iter().enumerate() {
            if *load > inst.r[j] + 1e-9 {
                return Err(format!(
                    "edge {j} overloaded: load {load:.3} > capacity {:.3}",
                    inst.r[j]
                ));
            }
        }
        // (6) minimum participation.
        if self.n_assigned() < inst.t_min {
            return Err(format!(
                "participation {} < T {}",
                self.n_assigned(),
                inst.t_min
            ));
        }
        Ok(())
    }
}

/// Given a fixed set of open edges, greedily complete a device assignment:
/// devices in decreasing-λ order (first-fit-decreasing flavor), each to its
/// cheapest open edge with residual capacity (ties: larger residual).
///
/// Returns None if fewer than `t_min` devices could be assigned.
/// Closes any edge that ends up unused (constraint 3).
pub fn complete_assignment(inst: &Instance, open: &[bool]) -> Option<Assignment> {
    let (n, m) = (inst.n(), inst.m());
    debug_assert_eq!(open.len(), m);
    let mut residual: Vec<f64> = (0..m)
        .map(|j| if open[j] { inst.r[j] } else { 0.0 })
        .collect();

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| inst.lambda[b].total_cmp(&inst.lambda[a]));

    let mut assign = vec![None; n];
    let mut assigned = 0usize;
    for &i in &order {
        let row = inst.c_d.row(i);
        let mut best: Option<usize> = None;
        for j in 0..m {
            if !open[j] || residual[j] + 1e-9 < inst.lambda[i] {
                continue;
            }
            best = match best {
                None => Some(j),
                Some(b) => {
                    let (cb, cj) = (row[b], row[j]);
                    if cj < cb - 1e-12 || (cj < cb + 1e-12 && residual[j] > residual[b]) {
                        Some(j)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        if let Some(j) = best {
            assign[i] = Some(j);
            residual[j] -= inst.lambda[i];
            assigned += 1;
        }
    }
    if assigned < inst.t_min {
        return None;
    }
    // Close unused edges (constraint 3) — cost never increases.
    let mut open = open.to_vec();
    for j in 0..m {
        if open[j] && !assign.iter().any(|&a| a == Some(j)) {
            open[j] = false;
        }
    }
    Some(Assignment { assign, open })
}

/// Incremental cost/feasibility state over one evolving assignment.
///
/// Mirrors an [`Assignment`] plus per-edge residual capacity, served-device
/// counts and the running Eq. 1 objective, so candidate moves are scored
/// and applied in O(1) instead of re-walking the whole assignment. Local
/// search and B&B incumbent polishing run on this; every mutation
/// cross-checks the running cost against a full recompute under
/// `debug_assertions`.
///
/// Invariants the *caller* maintains (the evaluator only tracks state):
/// open-but-empty edges are allowed mid-transaction — finish with
/// [`close_empty_edges`] to restore constraint (3) before extracting.
#[derive(Debug, Clone)]
pub struct IncrementalEvaluator<'a> {
    inst: &'a Instance,
    assign: Vec<Option<usize>>,
    open: Vec<bool>,
    residual: Vec<f64>,
    served: Vec<usize>,
    n_assigned: usize,
    cost: f64,
}

impl<'a> IncrementalEvaluator<'a> {
    /// Build from an existing assignment. O(n + m); every operation after
    /// this is O(1).
    pub fn new(inst: &'a Instance, sol: &Assignment) -> IncrementalEvaluator<'a> {
        let m = inst.m();
        debug_assert_eq!(sol.assign.len(), inst.n());
        debug_assert_eq!(sol.open.len(), m);
        let mut residual: Vec<f64> = inst.r.to_vec();
        let mut served = vec![0usize; m];
        let mut n_assigned = 0usize;
        for &a in &sol.assign {
            if let Some(j) = a {
                served[j] += 1;
                n_assigned += 1;
            }
        }
        for (i, &a) in sol.assign.iter().enumerate() {
            if let Some(j) = a {
                residual[j] -= inst.lambda[i];
            }
        }
        IncrementalEvaluator {
            inst,
            assign: sol.assign.clone(),
            open: sol.open.clone(),
            residual,
            served,
            n_assigned,
            cost: sol.cost(inst),
        }
    }

    /// The instance this evaluator scores against (outlives the borrow of
    /// `self`, so callers can hold it across mutations).
    pub fn instance(&self) -> &'a Instance {
        self.inst
    }

    /// Running Eq. 1 objective.
    pub fn cost(&self) -> f64 {
        self.cost
    }

    pub fn residual(&self, j: usize) -> f64 {
        self.residual[j]
    }

    pub fn served(&self, j: usize) -> usize {
        self.served[j]
    }

    pub fn is_open(&self, j: usize) -> bool {
        self.open[j]
    }

    pub fn assign_of(&self, i: usize) -> Option<usize> {
        self.assign[i]
    }

    pub fn n_assigned(&self) -> usize {
        self.n_assigned
    }

    /// Snapshot the current state as a plain [`Assignment`].
    pub fn assignment(&self) -> Assignment {
        Assignment { assign: self.assign.clone(), open: self.open.clone() }
    }

    /// Cost delta of moving assigned device `i` to edge `to`, or None if
    /// the move is inadmissible (unassigned device, same/closed target,
    /// no residual capacity). O(1).
    pub fn reassign_delta(&self, i: usize, to: usize) -> Option<f64> {
        let from = self.assign[i]?;
        if to == from || !self.open[to] || self.residual[to] + 1e-9 < self.inst.lambda[i] {
            return None;
        }
        Some(self.inst.l * (self.inst.c_d[i][to] - self.inst.c_d[i][from]))
    }

    /// Move assigned device `i` to edge `to`; returns the cost delta. O(1).
    /// The caller has checked admissibility (e.g. via [`Self::reassign_delta`]);
    /// rollbacks may re-apply moves without re-checking.
    pub fn apply_reassign(&mut self, i: usize, to: usize) -> f64 {
        let from = self.assign[i].expect("apply_reassign: device not assigned");
        debug_assert_ne!(from, to);
        let lam = self.inst.lambda[i];
        self.residual[from] += lam;
        self.served[from] -= 1;
        self.residual[to] -= lam;
        self.served[to] += 1;
        self.assign[i] = Some(to);
        let delta = self.inst.l * (self.inst.c_d[i][to] - self.inst.c_d[i][from]);
        self.cost += delta;
        self.debug_check();
        delta
    }

    /// Unassign device `i`; returns the cost delta. The caller is
    /// responsible for keeping participation ≥ t_min.
    pub fn apply_unassign(&mut self, i: usize) -> f64 {
        let from = self.assign[i].expect("apply_unassign: device not assigned");
        self.residual[from] += self.inst.lambda[i];
        self.served[from] -= 1;
        self.assign[i] = None;
        self.n_assigned -= 1;
        let delta = -self.inst.l * self.inst.c_d[i][from];
        self.cost += delta;
        self.debug_check();
        delta
    }

    /// Assign unassigned device `i` to open edge `to`; returns the delta.
    pub fn apply_assign(&mut self, i: usize, to: usize) -> f64 {
        debug_assert!(self.assign[i].is_none(), "apply_assign: device already assigned");
        self.residual[to] -= self.inst.lambda[i];
        self.served[to] += 1;
        self.assign[i] = Some(to);
        self.n_assigned += 1;
        let delta = self.inst.l * self.inst.c_d[i][to];
        self.cost += delta;
        self.debug_check();
        delta
    }

    /// Open edge `j` (pays `c_e[j]`); returns the delta.
    pub fn open_edge(&mut self, j: usize) -> f64 {
        debug_assert!(!self.open[j], "open_edge: already open");
        self.open[j] = true;
        self.cost += self.inst.c_e[j];
        self.debug_check();
        self.inst.c_e[j]
    }

    /// Close *empty* open edge `j` (recovers `c_e[j]`); returns the delta.
    pub fn close_edge(&mut self, j: usize) -> f64 {
        debug_assert!(self.open[j], "close_edge: not open");
        debug_assert_eq!(self.served[j], 0, "close_edge: edge still serves devices");
        self.open[j] = false;
        self.cost -= self.inst.c_e[j];
        self.debug_check();
        -self.inst.c_e[j]
    }

    /// Pin the running cost back to a checkpointed value after a rolled
    /// back transaction, discarding accumulated floating-point drift. The
    /// checkpoint must describe the state the evaluator is actually in.
    pub fn reset_cost(&mut self, cost: f64) {
        debug_assert!(
            (self.cost - cost).abs() <= 1e-6 * cost.abs().max(1.0),
            "reset_cost to {} but running cost is {} — rollback incomplete?",
            cost,
            self.cost
        );
        self.cost = cost;
    }

    /// Cross-check the running cost against the seed's full recompute.
    fn debug_check(&self) {
        #[cfg(debug_assertions)]
        {
            let full = self.assignment().cost(self.inst);
            debug_assert!(
                (self.cost - full).abs() <= 1e-6 * full.abs().max(1.0),
                "incremental cost {} diverged from full recompute {}",
                self.cost,
                full
            );
        }
    }
}

/// Close every open-but-empty edge (restores constraint 3; never
/// increases cost). Returns the total cost delta.
pub fn close_empty_edges(ev: &mut IncrementalEvaluator) -> f64 {
    let m = ev.instance().m();
    let mut delta = 0.0;
    for j in 0..m {
        if ev.is_open(j) && ev.served(j) == 0 {
            delta += ev.close_edge(j);
        }
    }
    delta
}

/// First-improvement device-reassignment sweeps: move each assigned device
/// to its cheapest feasible open edge until a sweep applies no move.
/// Every candidate is scored in O(1) via [`IncrementalEvaluator`]; the
/// whole pass is O(sweeps · n · m) with no completion re-runs. Returns the
/// number of applied moves.
pub fn refine_in_place(ev: &mut IncrementalEvaluator) -> usize {
    let inst = ev.instance();
    let (n, m) = (inst.n(), inst.m());
    let mut moves = 0usize;
    // Cost strictly decreases per move over a finite state space, so this
    // terminates; the sweep cap is belt-and-braces.
    for _sweep in 0..20 {
        let mut improved = false;
        for i in 0..n {
            let Some(cur) = ev.assign_of(i) else { continue };
            let row = inst.c_d.row(i);
            let mut best: Option<usize> = None;
            for j in 0..m {
                if j == cur || !ev.is_open(j) {
                    continue;
                }
                if row[j] < row[cur] - 1e-12 && ev.residual(j) + 1e-9 >= inst.lambda[i] {
                    let better = match best {
                        None => true,
                        Some(b) => row[j] < row[b],
                    };
                    if better {
                        best = Some(j);
                    }
                }
            }
            if let Some(j) = best {
                ev.apply_reassign(i, j);
                moves += 1;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    moves
}

/// Polish a feasible assignment with the incremental device sweeps and
/// close any edges they empty. Used by local search and for B&B incumbent
/// rounding; output cost ≤ input cost, feasibility preserved.
pub fn refine_assignment(inst: &Instance, sol: &Assignment) -> Assignment {
    let mut ev = IncrementalEvaluator::new(inst, sol);
    refine_in_place(&mut ev);
    close_empty_edges(&mut ev);
    ev.assignment()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;

    fn tiny() -> Instance {
        // 3 devices, 2 edges; device costs chosen by hand.
        Instance {
            c_d: vec![
                vec![0.0, 1.0],
                vec![1.0, 0.0],
                vec![1.0, 1.0],
            ]
            .into(),
            c_e: vec![5.0, 4.0],
            lambda: vec![1.0, 1.0, 1.0].into(),
            r: vec![2.0, 2.0].into(),
            l: 2.0,
            t_min: 3,
            meta: Default::default(),
        }
    }

    #[test]
    fn cost_formula() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(1), Some(0)],
            open: vec![true, true],
        };
        // local: (0 + 0 + 1) * l=2 -> 2 ; global: 5 + 4 = 9 -> total 11.
        assert!((a.cost(&inst) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn feasible_solution_passes() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(1), Some(1)],
            open: vec![true, true],
        };
        a.check_feasible(&inst).unwrap();
    }

    #[test]
    fn detects_closed_edge_assignment() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(0), None],
            open: vec![true, false],
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("participation") || err.contains("closed"));
    }

    #[test]
    fn detects_empty_open_edge() {
        let mut inst = tiny();
        inst.t_min = 2;
        inst.r = vec![3.0, 3.0].into();
        let a = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, true], // edge 1 open but unused
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("serves no device"), "{err}");
    }

    #[test]
    fn detects_overload() {
        let inst = tiny(); // capacity 2.0 each
        let a = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, false],
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("overloaded"), "{err}");
    }

    #[test]
    fn detects_low_participation() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(0), None],
            open: vec![true, false],
        };
        assert!(a.check_feasible(&inst).is_err());
    }

    #[test]
    fn complete_assignment_respects_capacity() {
        let inst = tiny();
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        sol.check_feasible(&inst).unwrap();
        let loads = sol.loads(&inst);
        assert!(loads.iter().zip(inst.r.iter()).all(|(l, r)| l <= r));
    }

    #[test]
    fn complete_assignment_prefers_cheap_edges() {
        let mut inst = tiny();
        inst.r = vec![10.0, 10.0].into(); // no capacity pressure
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        assert_eq!(sol.assign[0], Some(0)); // device 0 free at edge 0
        assert_eq!(sol.assign[1], Some(1)); // device 1 free at edge 1
    }

    #[test]
    fn complete_assignment_fails_when_capacity_short() {
        let mut inst = tiny();
        inst.r = vec![1.0, 1.0].into(); // only two devices fit, t_min = 3
        assert!(complete_assignment(&inst, &[true, true]).is_none());
    }

    #[test]
    fn complete_assignment_closes_unused() {
        let mut inst = tiny();
        inst.t_min = 2;
        inst.r = vec![5.0, 5.0].into();
        inst.c_d = vec![vec![0.0, 9.0], vec![0.0, 9.0], vec![0.0, 9.0]].into();
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        assert!(sol.open[0]);
        assert!(!sol.open[1], "unused edge should be closed");
        sol.check_feasible(&inst).unwrap();
    }

    #[test]
    fn complete_on_unit_cost_instance() {
        let inst = InstanceBuilder::unit_cost(50, 5, 3).build();
        let sol = complete_assignment(&inst, &[true; 5]).unwrap();
        sol.check_feasible(&inst).unwrap();
        assert_eq!(sol.n_assigned(), 50);
    }

    #[test]
    fn evaluator_tracks_reassign_and_open_close() {
        let mut inst = tiny();
        inst.r = vec![10.0, 10.0].into();
        let start = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, false],
        };
        let mut ev = IncrementalEvaluator::new(&inst, &start);
        let base = start.cost(&inst);
        assert_eq!(ev.cost(), base);
        assert_eq!(ev.served(0), 3);
        assert!((ev.residual(0) - 7.0).abs() < 1e-12);

        // Open edge 1, move device 1 there (cheaper: cost 0 vs 1).
        assert!((ev.open_edge(1) - 4.0).abs() < 1e-12);
        let delta = ev.reassign_delta(1, 1).unwrap();
        assert!((delta - inst.l * (0.0 - 1.0)).abs() < 1e-12);
        assert!((ev.apply_reassign(1, 1) - delta).abs() < 1e-12);
        assert_eq!(ev.served(0), 2);
        assert_eq!(ev.served(1), 1);
        let sol = ev.assignment();
        assert!((ev.cost() - sol.cost(&inst)).abs() < 1e-12);
        sol.check_feasible(&inst).unwrap();
    }

    #[test]
    fn evaluator_rejects_inadmissible_moves() {
        let inst = tiny(); // capacity 2.0 per edge
        let start = Assignment {
            assign: vec![Some(0), Some(1), Some(1)],
            open: vec![true, true],
        };
        let ev = IncrementalEvaluator::new(&inst, &start);
        assert!(ev.reassign_delta(0, 0).is_none(), "same edge");
        assert!(ev.reassign_delta(0, 1).is_none(), "edge 1 full (2.0/2.0)");
    }

    #[test]
    fn evaluator_unassign_assign_round_trip() {
        let inst = tiny();
        let start = Assignment {
            assign: vec![Some(0), Some(1), Some(1)],
            open: vec![true, true],
        };
        let mut ev = IncrementalEvaluator::new(&inst, &start);
        let c0 = ev.cost();
        let d1 = ev.apply_unassign(2);
        assert_eq!(ev.n_assigned(), 2);
        let d2 = ev.apply_assign(2, 1);
        assert_eq!(ev.n_assigned(), 3);
        assert!((d1 + d2).abs() < 1e-12);
        ev.reset_cost(c0);
        assert_eq!(ev.cost(), c0);
    }

    #[test]
    fn refine_moves_devices_to_cheaper_open_edges() {
        let mut inst = tiny();
        inst.r = vec![10.0, 10.0].into();
        // Everyone parked on edge 0; device 1 is cheaper at edge 1.
        let start = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, true],
        };
        let refined = refine_assignment(&inst, &start);
        assert_eq!(refined.assign[1], Some(1));
        assert!(refined.cost(&inst) <= start.cost(&inst));
        refined.check_feasible(&inst).unwrap();
    }

    #[test]
    fn refine_closes_emptied_edges() {
        let mut inst = tiny();
        inst.t_min = 2;
        inst.r = vec![10.0, 10.0].into();
        inst.c_d = vec![vec![0.0, 9.0], vec![0.0, 9.0], vec![0.0, 9.0]].into();
        // Device 2 sits alone on expensive edge 1; refining moves it to
        // edge 0 and the emptied edge closes.
        let start = Assignment {
            assign: vec![Some(0), Some(0), Some(1)],
            open: vec![true, true],
        };
        let refined = refine_assignment(&inst, &start);
        assert_eq!(refined.assign[2], Some(0));
        assert!(!refined.open[1]);
        refined.check_feasible(&inst).unwrap();
    }

    #[test]
    fn refine_never_worsens_random_instances() {
        for seed in 0..10 {
            let inst = InstanceBuilder::random(20, 4, seed).t_min(16).build();
            let Some(start) = complete_assignment(&inst, &[true; 4]) else { continue };
            let refined = refine_assignment(&inst, &start);
            assert!(refined.cost(&inst) <= start.cost(&inst) + 1e-9, "seed {seed}");
            refined.check_feasible(&inst).unwrap();
        }
    }
}
