//! Solution representation, feasibility checking, cost evaluation, and the
//! capacity-aware assignment-completion heuristic shared by the greedy,
//! local-search and branch & bound incumbent rounding.

use crate::hflop::Instance;

/// A (candidate) HFLOP solution: device→edge assignment + open aggregators.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// `assign[i] = Some(j)` if device i is served by edge j (x_ij = 1).
    pub assign: Vec<Option<usize>>,
    /// `open[j] = true` if an aggregator is placed at edge j (y_j = 1).
    pub open: Vec<bool>,
}

impl Assignment {
    pub fn empty(n: usize, m: usize) -> Assignment {
        Assignment { assign: vec![None; n], open: vec![false; m] }
    }

    pub fn n_assigned(&self) -> usize {
        self.assign.iter().filter(|a| a.is_some()).count()
    }

    pub fn n_open(&self) -> usize {
        self.open.iter().filter(|&&o| o).count()
    }

    /// Devices served by edge `j`.
    pub fn devices_of(&self, j: usize) -> Vec<usize> {
        self.assign
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| (a == Some(j)).then_some(i))
            .collect()
    }

    /// Objective value (Eq. 1): `Σ x_ij c_d[i][j] l + Σ y_j c_e[j]`.
    pub fn cost(&self, inst: &Instance) -> f64 {
        let local: f64 = self
            .assign
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| a.map(|j| inst.c_d[i][j]))
            .sum();
        let global: f64 = self
            .open
            .iter()
            .enumerate()
            .filter_map(|(j, &o)| o.then_some(inst.c_e[j]))
            .sum();
        local * inst.l + global
    }

    /// Load (Σ λ_i of assigned devices) per edge.
    pub fn loads(&self, inst: &Instance) -> Vec<f64> {
        let mut loads = vec![0.0; inst.m()];
        for (i, &a) in self.assign.iter().enumerate() {
            if let Some(j) = a {
                loads[j] += inst.lambda[i];
            }
        }
        loads
    }

    /// Check all HFLOP constraints (2)–(6). Returns a violation message.
    pub fn check_feasible(&self, inst: &Instance) -> Result<(), String> {
        let (n, m) = (inst.n(), inst.m());
        if self.assign.len() != n || self.open.len() != m {
            return Err("dimension mismatch".into());
        }
        // (2) x_ij <= y_j: assigned edge must be open.
        for (i, &a) in self.assign.iter().enumerate() {
            if let Some(j) = a {
                if j >= m {
                    return Err(format!("device {i} assigned to invalid edge {j}"));
                }
                if !self.open[j] {
                    return Err(format!("device {i} assigned to closed edge {j}"));
                }
            }
        }
        // (3) y_j <= sum_i x_ij: no empty open aggregator.
        for j in 0..m {
            if self.open[j] && !self.assign.iter().any(|&a| a == Some(j)) {
                return Err(format!("edge {j} open but serves no device"));
            }
        }
        // (4) capacity.
        for (j, load) in self.loads(inst).iter().enumerate() {
            if *load > inst.r[j] + 1e-9 {
                return Err(format!(
                    "edge {j} overloaded: load {load:.3} > capacity {:.3}",
                    inst.r[j]
                ));
            }
        }
        // (6) minimum participation.
        if self.n_assigned() < inst.t_min {
            return Err(format!(
                "participation {} < T {}",
                self.n_assigned(),
                inst.t_min
            ));
        }
        Ok(())
    }
}

/// Given a fixed set of open edges, greedily complete a device assignment:
/// devices in decreasing-λ order (first-fit-decreasing flavor), each to its
/// cheapest open edge with residual capacity (ties: larger residual).
///
/// Returns None if fewer than `t_min` devices could be assigned.
/// Closes any edge that ends up unused (constraint 3).
pub fn complete_assignment(inst: &Instance, open: &[bool]) -> Option<Assignment> {
    let (n, m) = (inst.n(), inst.m());
    debug_assert_eq!(open.len(), m);
    let mut residual: Vec<f64> = (0..m)
        .map(|j| if open[j] { inst.r[j] } else { 0.0 })
        .collect();

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| inst.lambda[b].partial_cmp(&inst.lambda[a]).unwrap());

    let mut assign = vec![None; n];
    let mut assigned = 0usize;
    for &i in &order {
        let mut best: Option<usize> = None;
        for j in 0..m {
            if !open[j] || residual[j] + 1e-9 < inst.lambda[i] {
                continue;
            }
            best = match best {
                None => Some(j),
                Some(b) => {
                    let (cb, cj) = (inst.c_d[i][b], inst.c_d[i][j]);
                    if cj < cb - 1e-12 || (cj < cb + 1e-12 && residual[j] > residual[b]) {
                        Some(j)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        if let Some(j) = best {
            assign[i] = Some(j);
            residual[j] -= inst.lambda[i];
            assigned += 1;
        }
    }
    if assigned < inst.t_min {
        return None;
    }
    // Close unused edges (constraint 3) — cost never increases.
    let mut open = open.to_vec();
    for j in 0..m {
        if open[j] && !assign.iter().any(|&a| a == Some(j)) {
            open[j] = false;
        }
    }
    Some(Assignment { assign, open })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;

    fn tiny() -> Instance {
        // 3 devices, 2 edges; device costs chosen by hand.
        Instance {
            c_d: vec![
                vec![0.0, 1.0],
                vec![1.0, 0.0],
                vec![1.0, 1.0],
            ],
            c_e: vec![5.0, 4.0],
            lambda: vec![1.0, 1.0, 1.0],
            r: vec![2.0, 2.0],
            l: 2.0,
            t_min: 3,
        }
    }

    #[test]
    fn cost_formula() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(1), Some(0)],
            open: vec![true, true],
        };
        // local: (0 + 0 + 1) * l=2 -> 2 ; global: 5 + 4 = 9 -> total 11.
        assert!((a.cost(&inst) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn feasible_solution_passes() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(1), Some(1)],
            open: vec![true, true],
        };
        a.check_feasible(&inst).unwrap();
    }

    #[test]
    fn detects_closed_edge_assignment() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(0), None],
            open: vec![true, false],
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("participation") || err.contains("closed"));
    }

    #[test]
    fn detects_empty_open_edge() {
        let mut inst = tiny();
        inst.t_min = 2;
        inst.r = vec![3.0, 3.0];
        let a = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, true], // edge 1 open but unused
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("serves no device"), "{err}");
    }

    #[test]
    fn detects_overload() {
        let inst = tiny(); // capacity 2.0 each
        let a = Assignment {
            assign: vec![Some(0), Some(0), Some(0)],
            open: vec![true, false],
        };
        let err = a.check_feasible(&inst).unwrap_err();
        assert!(err.contains("overloaded"), "{err}");
    }

    #[test]
    fn detects_low_participation() {
        let inst = tiny();
        let a = Assignment {
            assign: vec![Some(0), Some(0), None],
            open: vec![true, false],
        };
        assert!(a.check_feasible(&inst).is_err());
    }

    #[test]
    fn complete_assignment_respects_capacity() {
        let inst = tiny();
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        sol.check_feasible(&inst).unwrap();
        let loads = sol.loads(&inst);
        assert!(loads.iter().zip(&inst.r).all(|(l, r)| l <= r));
    }

    #[test]
    fn complete_assignment_prefers_cheap_edges() {
        let mut inst = tiny();
        inst.r = vec![10.0, 10.0]; // no capacity pressure
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        assert_eq!(sol.assign[0], Some(0)); // device 0 free at edge 0
        assert_eq!(sol.assign[1], Some(1)); // device 1 free at edge 1
    }

    #[test]
    fn complete_assignment_fails_when_capacity_short() {
        let mut inst = tiny();
        inst.r = vec![1.0, 1.0]; // only two devices fit, t_min = 3
        assert!(complete_assignment(&inst, &[true, true]).is_none());
    }

    #[test]
    fn complete_assignment_closes_unused() {
        let mut inst = tiny();
        inst.t_min = 2;
        inst.r = vec![5.0, 5.0];
        inst.c_d = vec![vec![0.0, 9.0], vec![0.0, 9.0], vec![0.0, 9.0]];
        let sol = complete_assignment(&inst, &[true, true]).unwrap();
        assert!(sol.open[0]);
        assert!(!sol.open[1], "unused edge should be closed");
        sol.check_feasible(&inst).unwrap();
    }

    #[test]
    fn complete_on_unit_cost_instance() {
        let inst = InstanceBuilder::unit_cost(50, 5, 3).build();
        let sol = complete_assignment(&inst, &[true; 5]).unwrap();
        sol.check_feasible(&inst).unwrap();
        assert_eq!(sol.n_assigned(), 50);
    }
}
