//! Contiguous row-major dense matrix.
//!
//! The seed implementation carried `Vec<Vec<f64>>` in every layer
//! (topology cost matrix, HFLOP instance, the simplex tableau); each row
//! was its own heap allocation, so row sweeps paid a pointer chase per
//! row. `DenseMatrix` stores one flat buffer and hands out row slices:
//! solver hot paths (pivot, candidate scoring) stay cache-friendly, and
//! whole-matrix clone/compare are single linear passes.
//!
//! `m[i]` indexes a row slice, so existing `m[i][j]` call sites read the
//! same as with nested vectors.

use std::ops::{Index, IndexMut};

/// A dense row-major `rows x cols` matrix of `f64`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing flat row-major buffer. Panics on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> DenseMatrix {
        assert_eq!(data.len(), rows * cols, "flat buffer len != rows*cols");
        DenseMatrix { rows, cols, data }
    }

    /// Build by evaluating `f(i, j)`. `f` is called in row-major order, so
    /// stateful closures (e.g. one RNG draw per row) see a deterministic
    /// visit order.
    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f64,
    ) -> DenseMatrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        DenseMatrix { rows, cols, data }
    }

    /// Build from nested rows. Panics if rows are ragged.
    pub fn from_rows(rows: Vec<Vec<f64>>) -> DenseMatrix {
        let n = rows.len();
        let m = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(n * m);
        for (i, row) in rows.into_iter().enumerate() {
            assert_eq!(row.len(), m, "ragged row {i}: len {} != {m}", row.len());
            data.extend(row);
        }
        DenseMatrix { rows: n, cols: m, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The flat row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Iterate rows as slices.
    pub fn row_iter(&self) -> std::slice::ChunksExact<'_, f64> {
        // `max(1)` keeps the degenerate 0-column matrix iterable (yields
        // no rows) instead of panicking inside chunks_exact.
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Multiply row `i` by `factor` (simplex pivot normalization).
    pub fn scale_row(&mut self, i: usize, factor: f64) {
        for v in self.row_mut(i) {
            *v *= factor;
        }
    }

    /// Disjoint mutable views of rows `a` and `b` (`a != b`), for in-place
    /// row updates like the pivot's `row_a -= f * row_b`.
    pub fn row_pair_mut(&mut self, a: usize, b: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(a, b, "row_pair_mut needs distinct rows");
        let c = self.cols;
        if a < b {
            let (lo, hi) = self.data.split_at_mut(b * c);
            (&mut lo[a * c..(a + 1) * c], &mut hi[..c])
        } else {
            let (lo, hi) = self.data.split_at_mut(a * c);
            (&mut hi[..c], &mut lo[b * c..(b + 1) * c])
        }
    }
}

/// `dst[k] += factor * src[k]` over the common prefix — the simplex pivot
/// inner loop.
#[inline]
pub fn axpy(dst: &mut [f64], src: &[f64], factor: f64) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += factor * s;
    }
}

impl Index<usize> for DenseMatrix {
    type Output = [f64];

    #[inline]
    fn index(&self, i: usize) -> &[f64] {
        self.row(i)
    }
}

impl IndexMut<usize> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut [f64] {
        self.row_mut(i)
    }
}

impl From<Vec<Vec<f64>>> for DenseMatrix {
    fn from(rows: Vec<Vec<f64>>) -> DenseMatrix {
        DenseMatrix::from_rows(rows)
    }
}

impl<'a> IntoIterator for &'a DenseMatrix {
    type Item = &'a [f64];
    type IntoIter = std::slice::ChunksExact<'a, f64>;

    fn into_iter(self) -> Self::IntoIter {
        self.row_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_round_trips() {
        let m = DenseMatrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m[0], [1.0, 2.0]);
        assert_eq!(m[1][0], 3.0);
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn from_rows_rejects_ragged() {
        DenseMatrix::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn from_fn_row_major_order() {
        let mut calls = Vec::new();
        let m = DenseMatrix::from_fn(2, 3, |i, j| {
            calls.push((i, j));
            (i * 3 + j) as f64
        });
        assert_eq!(calls, vec![(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]);
        assert_eq!(m[1], [3.0, 4.0, 5.0]);
    }

    #[test]
    fn row_iter_matches_rows() {
        let m = DenseMatrix::from_fn(3, 2, |i, j| (10 * i + j) as f64);
        let rows: Vec<&[f64]> = m.row_iter().collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2], &[20.0, 21.0]);
        let via_ref: Vec<&[f64]> = (&m).into_iter().collect();
        assert_eq!(rows, via_ref);
    }

    #[test]
    fn empty_matrix_is_harmless() {
        let m = DenseMatrix::default();
        assert_eq!(m.rows(), 0);
        assert_eq!(m.row_iter().count(), 0);
    }

    #[test]
    fn scale_and_row_pair() {
        let mut m = DenseMatrix::from_rows(vec![vec![2.0, 4.0], vec![1.0, 1.0]]);
        m.scale_row(0, 0.5);
        assert_eq!(m[0], [1.0, 2.0]);
        let (a, b) = m.row_pair_mut(1, 0);
        axpy(a, b, -1.0);
        assert_eq!(m[1], [0.0, -1.0]);
        // Order-agnostic: (hi, lo) view works too.
        let (r0, r1) = m.row_pair_mut(0, 1);
        r0[0] += r1[0];
        assert_eq!(m[0][0], 1.0);
    }

    #[test]
    fn index_mut_writes_through() {
        let mut m = DenseMatrix::zeros(2, 2);
        m[1][1] = 7.0;
        assert_eq!(m.row(1), &[0.0, 7.0]);
    }
}
