//! Workload / capacity vector newtypes.
//!
//! Thin wrappers over `Vec<f64>` that deref to `[f64]`, so call sites keep
//! slice ergonomics (`iter`, `len`, indexing, `to_vec`) while signatures
//! say which HFLOP quantity they carry — the two are summed against each
//! other in every feasibility check, and mixing them up type-checks fine
//! with bare vectors.

macro_rules! f64_vector {
    ($(#[$meta:meta])* $name:ident) => {
        $(#[$meta])*
        #[derive(Debug, Clone, PartialEq, Default)]
        pub struct $name(Vec<f64>);

        impl $name {
            pub fn new(values: Vec<f64>) -> $name {
                $name(values)
            }

            /// Sum of all entries.
            pub fn total(&self) -> f64 {
                self.0.iter().sum()
            }

            pub fn into_inner(self) -> Vec<f64> {
                self.0
            }
        }

        impl From<Vec<f64>> for $name {
            fn from(values: Vec<f64>) -> $name {
                $name(values)
            }
        }

        impl FromIterator<f64> for $name {
            fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> $name {
                $name(iter.into_iter().collect())
            }
        }

        impl std::ops::Deref for $name {
            type Target = [f64];

            fn deref(&self) -> &[f64] {
                &self.0
            }
        }

        impl std::ops::DerefMut for $name {
            fn deref_mut(&mut self) -> &mut [f64] {
                &mut self.0
            }
        }
    };
}

f64_vector!(
    /// Per-device inference request rates λ_i (requests/s) — §IV-A.
    Workload
);

f64_vector!(
    /// Per-edge inference processing capacities r_j (requests/s) — §IV-A.
    Capacity
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deref_gives_slice_api() {
        let w: Workload = vec![1.0, 2.0, 3.0].into();
        assert_eq!(w.len(), 3);
        assert_eq!(w[1], 2.0);
        assert_eq!(w.iter().sum::<f64>(), w.total());
        assert_eq!(w.to_vec(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn deref_mut_allows_in_place_edits() {
        let mut r: Capacity = vec![1.0, 1.0].into();
        for v in r.iter_mut() {
            *v = 5.0;
        }
        r[0] = 2.0;
        assert_eq!(r.into_inner(), vec![2.0, 5.0]);
    }

    #[test]
    fn collects_from_iterator() {
        let r: Capacity = (0..4).map(|i| i as f64).collect();
        assert_eq!(r.total(), 6.0);
    }
}
