//! Shared numeric core under topology → hflop → solvers → sim (DESIGN.md
//! §2): contiguous dense matrices and the workload/capacity vector
//! newtypes every layer above stores instead of carrying its own
//! `Vec<Vec<f64>>`.
//!
//! The types here are deliberately small: flat storage, row-slice
//! accessors, and the two pivot/axpy helpers the simplex hot path needs.
//! Anything problem-specific (costs, constraints, deltas) lives with the
//! problem, not here.

mod matrix;
mod vectors;

pub use matrix::{axpy, DenseMatrix};
pub use vectors::{Capacity, Workload};
