//! The original binary-heap event kernel, kept **verbatim** as a
//! differential-testing and benchmarking oracle.
//!
//! When `sim::kernel` moved to calendar-queue storage, this module froze
//! the pre-existing `BinaryHeap<Entry>` implementation (O(log n)
//! schedule/pop, O(len) `cancel` scan, O(heap) `invalidate_tag` scan) so
//! that:
//!
//! * `tests/kernel_differential.rs` can drive both kernels through the
//!   same random operation stream and assert bit-identical pop sequences
//!   and counters — the ordering contract is pinned by executable spec,
//!   not prose;
//! * `benches/bench_kernel.rs` can report events/sec speedups against the
//!   exact queue the repo used to run on.
//!
//! Do not "improve" this module: its value is that it does not change.
//! It is not wired into any production path.

// Frozen baseline: exempt from the hash-container ban (mirrored by the
// detlint exclusion in rust/lint.toml).
#![allow(clippy::disallowed_types)]

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Handle for one scheduled oracle timer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OracleTimerId(u64);

struct Entry<E> {
    time: f64,
    seq: u64,
    tag: Option<(u64, u64)>,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap on (time, seq).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The pre-calendar-queue kernel: `BinaryHeap` storage, lazy removal via
/// a cancelled-id hash set, O(len)/O(heap) cancellation scans.
pub struct HeapKernel<E> {
    heap: BinaryHeap<Entry<E>>,
    now: f64,
    seq: u64,
    processed: u64,
    cancelled_count: u64,
    live: usize,
    cancelled: HashSet<u64>,
    tag_gen: HashMap<u64, u64>,
}

impl<E> Default for HeapKernel<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapKernel<E> {
    pub fn new() -> HeapKernel<E> {
        HeapKernel {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
            cancelled_count: 0,
            live: 0,
            cancelled: HashSet::new(),
            tag_gen: HashMap::new(),
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn cancelled_count(&self) -> u64 {
        self.cancelled_count
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn push(&mut self, time: f64, tag: Option<(u64, u64)>, event: E) -> OracleTimerId {
        debug_assert!(time >= self.now - 1e-12, "scheduling into the past");
        let id = self.seq;
        self.heap.push(Entry { time: time.max(self.now), seq: id, tag, event });
        self.live += 1;
        self.seq += 1;
        OracleTimerId(id)
    }

    pub fn schedule(&mut self, time: f64, event: E) -> OracleTimerId {
        self.push(time, None, event)
    }

    pub fn schedule_in(&mut self, delay: f64, event: E) -> OracleTimerId {
        self.push(self.now + delay.max(0.0), None, event)
    }

    pub fn schedule_tagged(&mut self, time: f64, tag: u64, event: E) -> OracleTimerId {
        let gen = self.tag_gen.get(&tag).copied().unwrap_or(0);
        self.push(time, Some((tag, gen)), event)
    }

    pub fn schedule_tagged_in(&mut self, delay: f64, tag: u64, event: E) -> OracleTimerId {
        self.schedule_tagged(self.now + delay.max(0.0), tag, event)
    }

    /// Revoke one timer via the historical O(len) scan.
    pub fn cancel(&mut self, id: OracleTimerId) -> bool {
        if self.cancelled.contains(&id.0) {
            return false;
        }
        let alive = self.heap.iter().any(|e| e.seq == id.0 && !self.entry_dead(e));
        if alive {
            self.cancelled.insert(id.0);
            self.cancelled_count += 1;
            self.live -= 1;
            true
        } else {
            false
        }
    }

    /// Bump `tag`'s generation via the historical O(heap) victim count.
    pub fn invalidate_tag(&mut self, tag: u64) -> usize {
        let gen = self.tag_gen.entry(tag).or_insert(0);
        let old_gen = *gen;
        *gen += 1;
        let mut killed = 0;
        for e in self.heap.iter() {
            if let Some((t, g)) = e.tag {
                if t == tag && g == old_gen && !self.cancelled.contains(&e.seq) {
                    killed += 1;
                }
            }
        }
        self.cancelled_count += killed as u64;
        self.live -= killed;
        killed
    }

    pub fn generation(&self, tag: u64) -> u64 {
        self.tag_gen.get(&tag).copied().unwrap_or(0)
    }

    fn entry_dead(&self, e: &Entry<E>) -> bool {
        if !self.cancelled.is_empty() && self.cancelled.contains(&e.seq) {
            return true;
        }
        match e.tag {
            Some((tag, gen)) => gen < self.generation(tag),
            None => false,
        }
    }

    fn skim(&mut self) {
        loop {
            let dead = match self.heap.peek() {
                None => return,
                Some(e) => self.entry_dead(e),
            };
            if !dead {
                return;
            }
            let e = self.heap.pop().expect("peeked entry");
            self.cancelled.remove(&e.seq);
        }
    }

    pub fn peek_time(&mut self) -> Option<f64> {
        self.skim();
        self.heap.peek().map(|e| e.time)
    }

    /// Historical `clear`: tag generations and the clock are kept.
    pub fn clear(&mut self) {
        self.cancelled_count += self.live as u64;
        self.live = 0;
        self.heap.clear();
        self.cancelled.clear();
    }

    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.skim();
        let e = self.heap.pop()?;
        self.live -= 1;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.event))
    }

    pub fn next_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        match self.peek_time() {
            Some(t) if t < horizon => self.next(),
            _ => None,
        }
    }
}
