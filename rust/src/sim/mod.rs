//! Discrete-event simulation substrate.
//!
//! [`kernel::Kernel`] is the co-simulation kernel: a deterministic event
//! queue (f64 times, FIFO tie-break by insertion sequence) with
//! cancellable and generation-tagged timers, `peek_time`/`clear`, and the
//! [`kernel::Component`] trait that lets the serving, training and
//! control planes each handle their own events on one shared clock
//! (`inference::cosim`). Storage is a calendar queue over a slab arena;
//! [`oracle::HeapKernel`] preserves the original binary-heap
//! implementation as the differential-test and benchmark baseline.
//!
//! [`Des`] is the original minimal scheduler API, now a thin wrapper over
//! the kernel: events of user type `E` are scheduled at f64 times; ties
//! break by insertion sequence so runs are reproducible. The
//! static-assignment inference simulations (Fig. 7/8) and the cost sweeps
//! are built on this.

pub mod kernel;
pub mod oracle;

pub use kernel::{Component, Kernel, TimerId};

/// Deterministic discrete-event scheduler (no cancellation; the
/// historical API, kept for the static simulation paths).
pub struct Des<E> {
    k: Kernel<E>,
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Des<E> {
    pub fn new() -> Des<E> {
        Des { k: Kernel::new() }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.k.now()
    }

    pub fn processed(&self) -> u64 {
        self.k.processed()
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn len(&self) -> usize {
        self.k.len()
    }

    /// Schedule `event` at absolute time `time` (must be >= now).
    pub fn schedule(&mut self, time: f64, event: E) {
        self.k.schedule(time, event);
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.k.schedule_in(delay, event);
    }

    /// Pop the next event, advancing the clock.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.k.next()
    }

    /// Pop the next event only if it occurs before `horizon`.
    pub fn next_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        self.k.next_before(horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut des = Des::new();
        des.schedule(3.0, "c");
        des.schedule(1.0, "a");
        des.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut des = Des::new();
        des.schedule(1.0, 1);
        des.schedule(1.0, 2);
        des.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut des = Des::new();
        des.schedule(5.0, ());
        des.schedule(2.0, ());
        des.schedule(9.0, ());
        let mut last = 0.0;
        while let Some((t, _)) = des.next() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(des.now(), 9.0);
        assert_eq!(des.processed(), 3);
    }

    #[test]
    fn schedule_in_relative() {
        let mut des = Des::new();
        des.schedule(1.0, "first");
        des.next();
        des.schedule_in(0.5, "second");
        let (t, e) = des.next().unwrap();
        assert_eq!(e, "second");
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn next_before_horizon() {
        let mut des = Des::new();
        des.schedule(1.0, "a");
        des.schedule(5.0, "b");
        assert!(des.next_before(2.0).is_some());
        assert!(des.next_before(2.0).is_none());
        assert_eq!(des.len(), 1);
    }
}
