//! Discrete-event simulation substrate.
//!
//! A minimal, deterministic event queue: events of user type `E` are
//! scheduled at f64 times; ties break by insertion sequence so runs are
//! reproducible. The inference-serving simulations (Fig. 7/8) and the
//! cost sweeps are built on this.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled entry.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap on (time, seq). `total_cmp` keeps the heap
        // ordering a lawful total order even if a NaN time ever slips in
        // (partial_cmp would silently collapse it to Equal and corrupt
        // the queue's tie-breaking).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event scheduler.
pub struct Des<E> {
    heap: BinaryHeap<Entry<E>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Des<E> {
    pub fn new() -> Des<E> {
        Des { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute time `time` (must be >= now).
    pub fn schedule(&mut self, time: f64, event: E) {
        debug_assert!(time >= self.now - 1e-12, "scheduling into the past");
        self.heap.push(Entry { time: time.max(self.now), seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.schedule(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.event))
    }

    /// Pop the next event only if it occurs before `horizon`.
    pub fn next_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        match self.heap.peek() {
            Some(e) if e.time < horizon => self.next(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut des = Des::new();
        des.schedule(3.0, "c");
        des.schedule(1.0, "a");
        des.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut des = Des::new();
        des.schedule(1.0, 1);
        des.schedule(1.0, 2);
        des.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut des = Des::new();
        des.schedule(5.0, ());
        des.schedule(2.0, ());
        des.schedule(9.0, ());
        let mut last = 0.0;
        while let Some((t, _)) = des.next() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(des.now(), 9.0);
        assert_eq!(des.processed(), 3);
    }

    #[test]
    fn schedule_in_relative() {
        let mut des = Des::new();
        des.schedule(1.0, "first");
        des.next();
        des.schedule_in(0.5, "second");
        let (t, e) = des.next().unwrap();
        assert_eq!(e, "second");
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn next_before_horizon() {
        let mut des = Des::new();
        des.schedule(1.0, "a");
        des.schedule(5.0, "b");
        assert!(des.next_before(2.0).is_some());
        assert!(des.next_before(2.0).is_none());
        assert_eq!(des.len(), 1);
    }
}
