//! Co-simulation kernel: the generalized discrete-event scheduler.
//!
//! [`Kernel`] extends the original `Des` event queue with what a joint
//! training/serving/control co-simulation needs:
//!
//! * **cancellable timers** — [`Kernel::schedule`] returns a [`TimerId`]
//!   that [`Kernel::cancel`] can revoke before it fires (lazy removal,
//!   O(1) per cancel);
//! * **generation-tagged timers** — [`Kernel::schedule_tagged`] stamps an
//!   entry with a `(tag, generation)` pair; [`Kernel::invalidate_tag`]
//!   bumps the tag's generation so every *older* pending timer with that
//!   tag is dead, while timers scheduled afterwards live. This is how a
//!   mid-run deployment-plan swap cancels a failed edge's stale
//!   service-completion timers without touching the rest of the queue;
//! * **introspection** — [`Kernel::peek_time`], [`Kernel::clear`], live
//!   length, processed/cancelled counters.
//!
//! Ordering is identical to the original queue: `(time, seq)` min-heap,
//! so ties at equal timestamps break FIFO by insertion and every run is
//! reproducible. Cancelled entries never advance the clock and never
//! count as processed.
//!
//! [`Component`] is the plug-in trait for the co-simulation: serving,
//! training and control logic each handle their own events on the shared
//! clock, communicating only through scheduled events and a shared world
//! state (see `inference::cosim`).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Handle for one scheduled timer, usable to cancel it before it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

/// One scheduled entry.
struct Entry<E> {
    time: f64,
    seq: u64,
    /// `(tag, generation at schedule time)`; the entry is dead if the tag
    /// has been invalidated since.
    tag: Option<(u64, u64)>,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap on (time, seq). `total_cmp` keeps the heap
        // ordering a lawful total order even if a NaN time ever slips in.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event kernel with cancellable and
/// generation-tagged timers.
///
/// The hot path (schedule/next with no cancellation — the static Fig. 7/8
/// simulations) is pure heap operations plus a counter: the cancellation
/// bookkeeping sets are only consulted when non-empty, and individual
/// `cancel` pays an O(len) scan instead of taxing every event with
/// hash-set inserts.
pub struct Kernel<E> {
    heap: BinaryHeap<Entry<E>>,
    now: f64,
    seq: u64,
    processed: u64,
    cancelled_count: u64,
    /// Live (scheduled, not yet fired or cancelled) timer count.
    live: usize,
    /// Individually cancelled ids awaiting lazy removal from the heap.
    cancelled: HashSet<u64>,
    /// Current generation per tag; entries stamped with an older
    /// generation are dead.
    tag_gen: HashMap<u64, u64>,
}

impl<E> Default for Kernel<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Kernel<E> {
    pub fn new() -> Kernel<E> {
        Kernel {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
            cancelled_count: 0,
            live: 0,
            cancelled: HashSet::new(),
            tag_gen: HashMap::new(),
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events delivered so far (cancelled entries excluded).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Timers revoked so far (individually or via tag invalidation).
    pub fn cancelled_count(&self) -> u64 {
        self.cancelled_count
    }

    /// Number of live (non-cancelled) pending timers.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn push(&mut self, time: f64, tag: Option<(u64, u64)>, event: E) -> TimerId {
        debug_assert!(time >= self.now - 1e-12, "scheduling into the past");
        let id = self.seq;
        self.heap.push(Entry { time: time.max(self.now), seq: id, tag, event });
        self.live += 1;
        self.seq += 1;
        TimerId(id)
    }

    /// Schedule `event` at absolute time `time` (must be >= now).
    pub fn schedule(&mut self, time: f64, event: E) -> TimerId {
        self.push(time, None, event)
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: f64, event: E) -> TimerId {
        self.push(self.now + delay.max(0.0), None, event)
    }

    /// Schedule `event` at `time`, stamped with `tag`'s current
    /// generation: [`Kernel::invalidate_tag`] on that tag kills it.
    pub fn schedule_tagged(&mut self, time: f64, tag: u64, event: E) -> TimerId {
        let gen = self.tag_gen.get(&tag).copied().unwrap_or(0);
        self.push(time, Some((tag, gen)), event)
    }

    /// Tagged variant of [`Kernel::schedule_in`].
    pub fn schedule_tagged_in(&mut self, delay: f64, tag: u64, event: E) -> TimerId {
        self.schedule_tagged(self.now + delay.max(0.0), tag, event)
    }

    /// Revoke one timer. Returns true if it was still pending.
    ///
    /// O(len) scan: individual cancellation is a rare control-plane
    /// operation; paying here keeps the schedule/next hot path free of
    /// per-event hash-set bookkeeping.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        if self.cancelled.contains(&id.0) {
            return false;
        }
        let alive = self.heap.iter().any(|e| e.seq == id.0 && !self.entry_dead(e));
        if alive {
            self.cancelled.insert(id.0);
            self.cancelled_count += 1;
            self.live -= 1;
            true
        } else {
            false
        }
    }

    /// Bump `tag`'s generation: every pending timer scheduled under the
    /// old generation is dead; timers tagged afterwards are unaffected.
    /// Returns how many live timers this killed.
    pub fn invalidate_tag(&mut self, tag: u64) -> usize {
        let gen = self.tag_gen.entry(tag).or_insert(0);
        let old_gen = *gen;
        *gen += 1;
        // Count the victims so len() stays truthful; heap entries are
        // removed lazily on pop. Entries under generations older than
        // `old_gen` were already dead (counted at their own
        // invalidation), as were individually cancelled ones.
        let mut killed = 0;
        for e in self.heap.iter() {
            if let Some((t, g)) = e.tag {
                if t == tag && g == old_gen && !self.cancelled.contains(&e.seq) {
                    killed += 1;
                }
            }
        }
        self.cancelled_count += killed as u64;
        self.live -= killed;
        killed
    }

    /// Current generation of `tag` (0 if never invalidated).
    pub fn generation(&self, tag: u64) -> u64 {
        self.tag_gen.get(&tag).copied().unwrap_or(0)
    }

    fn entry_dead(&self, e: &Entry<E>) -> bool {
        if !self.cancelled.is_empty() && self.cancelled.contains(&e.seq) {
            return true;
        }
        match e.tag {
            Some((tag, gen)) => gen < self.generation(tag),
            None => false,
        }
    }

    /// Drop dead entries off the heap front; afterwards the front (if
    /// any) is live. Dead entries were already counted (and removed from
    /// the live count) by `cancel`/`invalidate_tag`.
    fn skim(&mut self) {
        loop {
            let dead = match self.heap.peek() {
                None => return,
                Some(e) => self.entry_dead(e),
            };
            if !dead {
                return;
            }
            let e = self.heap.pop().expect("peeked entry");
            self.cancelled.remove(&e.seq);
        }
    }

    /// Time of the next live event without delivering it.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.skim();
        self.heap.peek().map(|e| e.time)
    }

    /// Drop every pending timer without delivering (tag generations and
    /// the clock are kept).
    pub fn clear(&mut self) {
        self.cancelled_count += self.live as u64;
        self.live = 0;
        self.heap.clear();
        self.cancelled.clear();
    }

    /// Pop the next live event, advancing the clock.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.skim();
        let e = self.heap.pop()?;
        self.live -= 1;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.event))
    }

    /// Pop the next live event only if it occurs before `horizon`.
    pub fn next_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        match self.peek_time() {
            Some(t) if t < horizon => self.next(),
            _ => None,
        }
    }
}

/// One plane of a co-simulation: handles the events addressed to it,
/// scheduling follow-ups on the shared kernel and communicating with the
/// other planes only through events and the shared world state `S`.
pub trait Component<E, S> {
    fn name(&self) -> &'static str {
        "component"
    }

    fn handle(&mut self, now: f64, event: E, kernel: &mut Kernel<E>, shared: &mut S);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_and_fifo_at_ties() {
        let mut k = Kernel::new();
        k.schedule(3.0, "c");
        k.schedule(1.0, "a1");
        k.schedule(1.0, "a2");
        k.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| k.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a1", "a2", "b", "c"]);
        assert_eq!(k.processed(), 4);
    }

    #[test]
    fn cancel_skips_timer() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.schedule(2.0, "b");
        assert_eq!(k.len(), 2);
        assert!(k.cancel(a));
        assert!(!k.cancel(a), "double cancel is a no-op");
        assert_eq!(k.len(), 1);
        let (t, e) = k.next().unwrap();
        assert_eq!((t, e), (2.0, "b"));
        assert!(k.next().is_none());
        assert_eq!(k.processed(), 1);
        assert_eq!(k.cancelled_count(), 1);
    }

    #[test]
    fn cancel_after_fire_returns_false() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.next().unwrap();
        assert!(!k.cancel(a));
    }

    #[test]
    fn invalidate_tag_kills_only_older_generation() {
        let mut k = Kernel::new();
        k.schedule_tagged(1.0, 7, "old1");
        k.schedule_tagged(2.0, 7, "old2");
        k.schedule_tagged(1.5, 8, "other-tag");
        assert_eq!(k.invalidate_tag(7), 2);
        k.schedule_tagged(3.0, 7, "new");
        let order: Vec<&str> = std::iter::from_fn(|| k.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["other-tag", "new"]);
        assert_eq!(k.cancelled_count(), 2);
        assert_eq!(k.generation(7), 1);
        assert_eq!(k.generation(8), 0);
    }

    #[test]
    fn peek_time_skips_dead_entries() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.schedule(2.0, "b");
        k.cancel(a);
        assert_eq!(k.peek_time(), Some(2.0));
        // Peeking does not advance the clock or deliver.
        assert_eq!(k.now(), 0.0);
        assert_eq!(k.next().unwrap().1, "b");
    }

    #[test]
    fn clear_empties_queue() {
        let mut k = Kernel::new();
        k.schedule(1.0, 1);
        k.schedule(2.0, 2);
        k.clear();
        assert!(k.is_empty());
        assert!(k.next().is_none());
        assert_eq!(k.cancelled_count(), 2);
        // Still usable afterwards.
        k.schedule(5.0, 3);
        assert_eq!(k.next().unwrap(), (5.0, 3));
    }

    #[test]
    fn next_before_horizon() {
        let mut k = Kernel::new();
        k.schedule(1.0, "a");
        k.schedule(5.0, "b");
        assert!(k.next_before(2.0).is_some());
        assert!(k.next_before(2.0).is_none());
        assert_eq!(k.len(), 1);
    }

    #[test]
    fn fifo_property_at_equal_timestamps() {
        // Property: at any fixed timestamp, live events pop in insertion
        // order, regardless of interleaved cancels at the same time.
        let mut rng = crate::util::rng::Rng::new(99);
        let mut k = Kernel::new();
        let mut expect: Vec<(u64, usize)> = Vec::new(); // (time-as-int, payload)
        let mut cancels = Vec::new();
        for i in 0..500 {
            let t = rng.below(10) as f64;
            let id = k.schedule(t, i);
            if rng.chance(0.2) {
                cancels.push(id);
            } else {
                expect.push((t as u64, i));
            }
        }
        for id in cancels {
            assert!(k.cancel(id));
        }
        // Stable sort by time preserves insertion order within a tie —
        // exactly the kernel's contract.
        expect.sort_by_key(|&(t, _)| t);
        let got: Vec<(u64, usize)> =
            std::iter::from_fn(|| k.next().map(|(t, e)| (t as u64, e))).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn reschedule_under_new_generation_survives() {
        let mut k = Kernel::new();
        k.schedule_tagged(1.0, 3, "stale");
        k.invalidate_tag(3);
        k.invalidate_tag(3);
        k.schedule_tagged(1.0, 3, "fresh");
        assert_eq!(k.len(), 1);
        assert_eq!(k.next().unwrap().1, "fresh");
    }
}
