//! Co-simulation kernel: the generalized discrete-event scheduler.
//!
//! [`Kernel`] extends the original `Des` event queue with what a joint
//! training/serving/control co-simulation needs:
//!
//! * **cancellable timers** — [`Kernel::schedule`] returns a [`TimerId`]
//!   that [`Kernel::cancel`] can revoke before it fires (O(1): the slab
//!   slot's alive bit flips, the entry is reaped lazily);
//! * **generation-tagged timers** — [`Kernel::schedule_tagged`] stamps an
//!   entry with a `(tag, generation)` pair; [`Kernel::invalidate_tag`]
//!   bumps the tag's generation so every *older* pending timer with that
//!   tag is dead, while timers scheduled afterwards live. This is how a
//!   mid-run deployment-plan swap cancels a failed edge's stale
//!   service-completion timers without touching the rest of the queue;
//! * **introspection** — [`Kernel::peek_time`], [`Kernel::clear`],
//!   [`Kernel::reset`], live length, processed/cancelled counters.
//!
//! # Storage: calendar queue over a slab arena
//!
//! Timer storage is a bucketed **calendar queue**, not a binary heap (the
//! original heap implementation survives verbatim as
//! [`crate::sim::oracle::HeapKernel`] for differential tests and
//! benchmarks). Every entry lives in a flat slab (`Vec<Slot<E>>`) with a
//! free list, so the steady-state schedule→fire cycle recycles slots and
//! never allocates. The queue itself has three tiers:
//!
//! * a **near wheel** of `N` buckets of width `w`, bucket `i` covering
//!   `[base + i*w, base + (i+1)*w)`. Scheduling is an index computation
//!   plus a `Vec` push; firing drains one bucket at a time;
//! * a **drain vec** (`cur`) holding the bucket currently being fired,
//!   sorted by `(time, seq)` descending so popping the minimum is a
//!   `Vec::pop`. Entries scheduled into the already-drained region of the
//!   wheel (e.g. `schedule_in(0.0)` from an event handler) are
//!   binary-search inserted here;
//! * an **overflow tier** for timers beyond the wheel's window
//!   (far-future round timers, `gap_s = 1e9` idle schedules). It is an
//!   unordered `Vec`, redistributed wholesale when the wheel empties.
//!
//! When the wheel runs dry the kernel *re-anchors*: every live entry is
//! collected, sorted once, and redistributed around a fresh `base = t_min`
//! with geometry picked from the data — bucket count is the live count
//! rounded to a power of two (clamped to `[64, 65536]`) and the width
//! spreads the 75th-percentile span at ~one entry per bucket, so a handful
//! of far-future outliers cannot stretch the buckets into sorted-list
//! degeneracy. The same rebuild runs when the live count outgrows the
//! wheel (doubling amortizes it to O(log n) per event).
//!
//! # Ordering contract
//!
//! Delivery order is **identical** to the original heap queue: strict
//! `(time, seq)` order, so ties at equal timestamps break FIFO by
//! insertion and every run is reproducible bit-for-bit. This holds for
//! any bucket geometry because classification `t -> bucket` is monotone
//! (IEEE division and floor are monotone non-decreasing), equal times
//! always map to the same bucket, and each bucket is sorted before it
//! fires; the differential test in `tests/kernel_differential.rs` pins
//! this against the heap oracle. Cancelled entries never advance the
//! clock and never count as processed.
//!
//! # Retention contract (`clear` vs `reset`)
//!
//! [`Kernel::clear`] drops pending timers but deliberately **keeps** the
//! clock, the `seq` counter, the processed/cancelled counters, and every
//! tag's generation — a cleared kernel is the same timeline with its
//! future revoked, so stale [`TimerId`]s stay dead and re-scheduled tags
//! keep their generation history. [`Kernel::reset`] is the full
//! reclamation: counters, clock, tag generations and slab contents all
//! return to the pristine state while the allocated slab/bucket capacity
//! is retained, which is what `inference::cosim::run_cell_reusing` uses
//! to run many cells on one warm kernel.
//!
//! [`Component`] is the plug-in trait for the co-simulation: serving,
//! training and control logic each handle their own events on the shared
//! clock, communicating only through scheduled events and a shared world
//! state (see `inference::cosim`).

// BTreeMap, not HashMap: `clear()` sweeps `tags.values_mut()`, and any
// future iteration must see a deterministic order (the hash-iteration
// lint rule; DESIGN.md §9).
use std::collections::BTreeMap;

/// Smallest/largest wheel sizes; powers of two so `next_power_of_two`
/// clamps cleanly.
const MIN_BUCKETS: usize = 64;
const MAX_BUCKETS: usize = 1 << 16;
/// Floor on bucket width so degenerate spans cannot divide to zero.
const MIN_WIDTH: f64 = 1e-9;

/// Handle for one scheduled timer, usable to cancel it before it fires.
///
/// Internally a `(slab slot, reuse stamp)` pair: the stamp is bumped each
/// time the slot is recycled, so a stale id for a fired timer fails the
/// stamp check instead of cancelling an unrelated newer timer. (A stamp
/// only repeats after 2^32 reuses of one slot.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId {
    slot: u32,
    stamp: u32,
}

/// One slab slot. `alive` is the O(1) cancellation bit; a dead slot stays
/// in whatever tier holds it until the drain loop reaps it.
struct Slot<E> {
    time: f64,
    seq: u64,
    stamp: u32,
    alive: bool,
    /// `(tag, generation at schedule time)`; the entry is dead if the tag
    /// has been invalidated since.
    tag: Option<(u64, u64)>,
    event: Option<E>,
}

/// Per-tag state: current generation plus the live count of
/// current-generation entries, maintained on schedule/fire/cancel so
/// [`Kernel::invalidate_tag`] is O(1) and `len()` stays truthful.
#[derive(Default)]
struct TagState {
    gen: u64,
    live: usize,
}

/// Deterministic discrete-event kernel with cancellable and
/// generation-tagged timers (calendar-queue storage; see module docs).
///
/// The hot path (schedule/next with no cancellation — the static Fig. 7/8
/// simulations) is an index computation plus slab/bucket `Vec` traffic:
/// no per-event allocation and no hash lookups for untagged timers.
pub struct Kernel<E> {
    slots: Vec<Slot<E>>,
    free: Vec<u32>,
    /// Near wheel: `buckets[i]` covers `[base + i*width, base + (i+1)*width)`.
    buckets: Vec<Vec<u32>>,
    /// Next wheel bucket to drain; buckets before it are empty and new
    /// entries mapping there go straight into `cur`.
    next_bucket: usize,
    base: f64,
    width: f64,
    /// Drain staging, sorted by `(time, seq)` descending (pop from back).
    cur: Vec<u32>,
    /// Far-future tier, unordered; redistributed at re-anchor.
    overflow: Vec<u32>,
    now: f64,
    seq: u64,
    processed: u64,
    cancelled_count: u64,
    /// Live (scheduled, not yet fired or cancelled) timer count.
    live: usize,
    tags: BTreeMap<u64, TagState>,
}

impl<E> Default for Kernel<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Kernel<E> {
    pub fn new() -> Kernel<E> {
        Kernel {
            slots: Vec::new(),
            free: Vec::new(),
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            next_bucket: 0,
            base: 0.0,
            width: 1.0,
            cur: Vec::new(),
            overflow: Vec::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
            cancelled_count: 0,
            live: 0,
            tags: BTreeMap::new(),
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events delivered so far (cancelled entries excluded).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Timers revoked so far (individually or via tag invalidation).
    pub fn cancelled_count(&self) -> u64 {
        self.cancelled_count
    }

    /// Number of live (non-cancelled) pending timers.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    // ---- slab -----------------------------------------------------------

    fn alloc(&mut self, time: f64, tag: Option<(u64, u64)>, event: E) -> u32 {
        let seq = self.seq;
        self.seq += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            s.time = time;
            s.seq = seq;
            s.alive = true;
            s.tag = tag;
            s.event = Some(event);
            idx
        } else {
            let idx = u32::try_from(self.slots.len()).expect("kernel slab exceeds u32 slots");
            self.slots.push(Slot { time, seq, stamp: 0, alive: true, tag, event: Some(event) });
            idx
        }
    }

    /// Return a slot to the free list, bumping its reuse stamp so stale
    /// [`TimerId`]s can no longer address it. Callers must have removed
    /// `idx` from its tier first.
    fn free_slot(&mut self, idx: u32) {
        let s = &mut self.slots[idx as usize];
        s.alive = false;
        s.event = None;
        s.tag = None;
        s.stamp = s.stamp.wrapping_add(1);
        self.free.push(idx);
    }

    /// Dead = individually cancelled (alive bit) or stamped with a
    /// superseded tag generation.
    fn slot_dead(&self, idx: u32) -> bool {
        let s = &self.slots[idx as usize];
        if !s.alive {
            return true;
        }
        match s.tag {
            Some((tag, gen)) => gen < self.tags.get(&tag).map_or(0, |t| t.gen),
            None => false,
        }
    }

    // ---- calendar placement ---------------------------------------------

    /// Route a freshly scheduled slot to its tier. Classification is a
    /// pure monotone function of the entry time (for fixed geometry), so
    /// earlier times never land in a later tier — the ordering proof in
    /// the module docs leans on exactly this.
    fn place(&mut self, idx: u32) {
        let t = self.slots[idx as usize].time;
        let nb = self.buckets.len();
        let rel = (t - self.base) / self.width;
        if !(rel < nb as f64) {
            // Beyond the wheel window (or non-finite): far-future tier.
            self.overflow.push(idx);
            return;
        }
        let b = if rel > 0.0 { rel as usize } else { 0 };
        if b < self.next_bucket {
            // The wheel already passed this bucket; the entry belongs to
            // the region currently being drained.
            self.cur_insert(idx);
        } else {
            self.buckets[b].push(idx);
        }
    }

    /// Binary-search insert into the descending-sorted drain vec.
    fn cur_insert(&mut self, idx: u32) {
        let (t, seq) = {
            let s = &self.slots[idx as usize];
            (s.time, s.seq)
        };
        let slots = &self.slots;
        let pos = self.cur.partition_point(|&i| {
            let s = &slots[i as usize];
            match s.time.total_cmp(&t) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Equal => s.seq > seq,
            }
        });
        self.cur.insert(pos, idx);
    }

    fn sort_cur(&mut self) {
        let slots = &self.slots;
        self.cur.sort_unstable_by(|&a, &b| {
            let (sa, sb) = (&slots[a as usize], &slots[b as usize]);
            sb.time.total_cmp(&sa.time).then_with(|| sb.seq.cmp(&sa.seq))
        });
    }

    /// Collect every live entry, free the dead, and redistribute around a
    /// fresh anchor with data-driven geometry. O(live log live); runs at
    /// re-anchor (wheel drained) and on live-count doubling, so it
    /// amortizes to O(log live) per event.
    fn rebuild(&mut self) {
        let mut entries: Vec<u32> = Vec::with_capacity(self.live);
        for i in 0..self.cur.len() {
            entries.push(self.cur[i]);
        }
        self.cur.clear();
        for b in 0..self.buckets.len() {
            let mut v = std::mem::take(&mut self.buckets[b]);
            entries.append(&mut v);
            self.buckets[b] = v; // hand the capacity back
        }
        entries.append(&mut self.overflow);
        // Free the dead before computing geometry.
        let mut w = 0;
        for r in 0..entries.len() {
            let idx = entries[r];
            if self.slot_dead(idx) {
                self.free_slot(idx);
            } else {
                entries[w] = idx;
                w += 1;
            }
        }
        entries.truncate(w);
        debug_assert_eq!(entries.len(), self.live, "live count drifted from slab contents");

        self.next_bucket = 0;
        if entries.is_empty() {
            self.base = self.now;
            return;
        }
        let slots = &self.slots;
        entries.sort_unstable_by(|&a, &b| {
            let (sa, sb) = (&slots[a as usize], &slots[b as usize]);
            sa.time.total_cmp(&sb.time).then_with(|| sa.seq.cmp(&sb.seq))
        });
        let k = entries.len();
        let tmin = self.slots[entries[0] as usize].time;
        // Geometry: spread the 75th-percentile span at ~one entry per
        // bucket, so far-future outliers don't inflate the width.
        let q = (3 * k).div_ceil(4).max(1);
        let span = self.slots[entries[q - 1] as usize].time - tmin;
        let target_n = k.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        self.width = if span > 0.0 { (span / q as f64).max(MIN_WIDTH) } else { 1.0 };
        self.base = tmin;
        if self.buckets.len() != target_n {
            self.buckets.resize_with(target_n, Vec::new);
        }
        for &idx in &entries {
            // `next_bucket` is 0 so nothing routes to `cur` here.
            let t = self.slots[idx as usize].time;
            let rel = (t - self.base) / self.width;
            if !(rel < target_n as f64) {
                self.overflow.push(idx);
            } else {
                let b = if rel > 0.0 { rel as usize } else { 0 };
                self.buckets[b].push(idx);
            }
        }
        if self.overflow.len() == k {
            // Non-finite times defeated classification; force progress by
            // draining everything through bucket 0 (it still sorts).
            let mut v = std::mem::take(&mut self.overflow);
            self.buckets[0].append(&mut v);
            self.overflow = v;
        }
    }

    /// Free every slot still held by a tier (used by `clear`/`reset`; the
    /// live count must already be settled by the caller).
    fn reap_all(&mut self) {
        for i in 0..self.cur.len() {
            let idx = self.cur[i];
            self.free_slot(idx);
        }
        self.cur.clear();
        for b in 0..self.buckets.len() {
            for i in 0..self.buckets[b].len() {
                let idx = self.buckets[b][i];
                self.free_slot(idx);
            }
            self.buckets[b].clear();
        }
        for i in 0..self.overflow.len() {
            let idx = self.overflow[i];
            self.free_slot(idx);
        }
        self.overflow.clear();
    }

    /// Ensure the back of `cur` is the next live entry. Returns false iff
    /// the queue is (live-)empty, reaping leftover dead entries so the
    /// slab gets reused.
    fn settle(&mut self) -> bool {
        loop {
            while let Some(&idx) = self.cur.last() {
                if self.slot_dead(idx) {
                    self.cur.pop();
                    self.free_slot(idx);
                } else {
                    return true;
                }
            }
            if self.next_bucket < self.buckets.len() {
                let b = self.next_bucket;
                self.next_bucket += 1;
                if self.buckets[b].is_empty() {
                    continue;
                }
                let mut moved = std::mem::take(&mut self.buckets[b]);
                for &idx in &moved {
                    if self.slot_dead(idx) {
                        self.free_slot(idx);
                    } else {
                        self.cur.push(idx);
                    }
                }
                moved.clear();
                self.buckets[b] = moved;
                self.sort_cur();
                continue;
            }
            if self.live == 0 {
                self.reap_all();
                self.next_bucket = 0;
                self.base = self.now;
                return false;
            }
            // Wheel drained but live entries remain in overflow:
            // re-anchor around them.
            self.rebuild();
        }
    }

    // ---- public scheduling API ------------------------------------------

    fn push(&mut self, time: f64, tag: Option<(u64, u64)>, event: E) -> TimerId {
        debug_assert!(time >= self.now - 1e-12, "scheduling into the past");
        let time = time.max(self.now);
        if self.live + 1 > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            // Grow before admitting the new entry: the rebuild
            // redistributes everything already queued and `place` files
            // the newcomer under the fresh geometry.
            self.rebuild();
        }
        let idx = self.alloc(time, tag, event);
        self.live += 1;
        self.place(idx);
        TimerId { slot: idx, stamp: self.slots[idx as usize].stamp }
    }

    /// Schedule `event` at absolute time `time` (must be >= now).
    pub fn schedule(&mut self, time: f64, event: E) -> TimerId {
        self.push(time, None, event)
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: f64, event: E) -> TimerId {
        self.push(self.now + delay.max(0.0), None, event)
    }

    /// Schedule `event` at `time`, stamped with `tag`'s current
    /// generation: [`Kernel::invalidate_tag`] on that tag kills it.
    pub fn schedule_tagged(&mut self, time: f64, tag: u64, event: E) -> TimerId {
        let st = self.tags.entry(tag).or_default();
        st.live += 1;
        let gen = st.gen;
        self.push(time, Some((tag, gen)), event)
    }

    /// Tagged variant of [`Kernel::schedule_in`].
    pub fn schedule_tagged_in(&mut self, delay: f64, tag: u64, event: E) -> TimerId {
        self.schedule_tagged(self.now + delay.max(0.0), tag, event)
    }

    /// Revoke one timer. Returns true if it was still pending.
    ///
    /// O(1): flips the slab slot's alive bit after a stamp check; the
    /// entry is reaped lazily when the drain reaches it.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        let Some(s) = self.slots.get(id.slot as usize) else { return false };
        if s.stamp != id.stamp || !s.alive {
            return false;
        }
        let tag = s.tag;
        if let Some((t, gen)) = tag {
            if gen < self.tags.get(&t).map_or(0, |ts| ts.gen) {
                // Already dead via tag invalidation; cancelling it again
                // is a no-op (and was already counted).
                return false;
            }
        }
        let s = &mut self.slots[id.slot as usize];
        s.alive = false;
        s.event = None;
        if let Some((t, _)) = tag {
            let ts = self.tags.get_mut(&t).expect("tagged entry without tag state");
            ts.live -= 1;
        }
        self.cancelled_count += 1;
        self.live -= 1;
        true
    }

    /// Bump `tag`'s generation: every pending timer scheduled under the
    /// old generation is dead; timers tagged afterwards are unaffected.
    /// Returns how many live timers this killed.
    ///
    /// O(1): the per-tag live count is maintained on schedule, fire and
    /// cancel, so invalidation never scans the queue.
    pub fn invalidate_tag(&mut self, tag: u64) -> usize {
        let st = self.tags.entry(tag).or_default();
        st.gen += 1;
        let killed = st.live;
        st.live = 0;
        self.cancelled_count += killed as u64;
        self.live -= killed;
        killed
    }

    /// Current generation of `tag` (0 if never invalidated).
    pub fn generation(&self, tag: u64) -> u64 {
        self.tags.get(&tag).map_or(0, |t| t.gen)
    }

    /// Time of the next live event without delivering it.
    pub fn peek_time(&mut self) -> Option<f64> {
        if !self.settle() {
            return None;
        }
        let idx = *self.cur.last().expect("settle returned true");
        Some(self.slots[idx as usize].time)
    }

    /// Drop every pending timer without delivering.
    ///
    /// Retention contract: the clock, `seq` counter, processed/cancelled
    /// counters and **every tag's generation** survive — a cleared kernel
    /// is the same timeline with its future revoked, so stale ids stay
    /// dead and re-scheduled tags keep their generation history. Use
    /// [`Kernel::reset`] to reclaim everything.
    pub fn clear(&mut self) {
        self.cancelled_count += self.live as u64;
        self.live = 0;
        self.reap_all();
        for st in self.tags.values_mut() {
            st.live = 0;
        }
        self.next_bucket = 0;
        self.base = self.now;
    }

    /// Return the kernel to its pristine just-constructed state — clock,
    /// counters, tag generations and pending timers all reclaimed — while
    /// keeping the slab, free-list and bucket capacity warm. This is the
    /// between-cells reset for batch runs (`run_cell_reusing`): a reset
    /// kernel delivers bit-identical schedules to a fresh `Kernel::new()`
    /// because ordering depends only on `(time, seq)`, never on geometry.
    pub fn reset(&mut self) {
        self.reap_all();
        self.tags.clear();
        self.now = 0.0;
        self.seq = 0;
        self.processed = 0;
        self.cancelled_count = 0;
        self.live = 0;
        self.next_bucket = 0;
        self.base = 0.0;
    }

    /// Pop the next live event, advancing the clock.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, E)> {
        if !self.settle() {
            return None;
        }
        let idx = self.cur.pop().expect("settle returned true");
        let (t, tag, event) = {
            let s = &mut self.slots[idx as usize];
            (s.time, s.tag, s.event.take().expect("live slot holds an event"))
        };
        if let Some((tag, _gen)) = tag {
            // A live fire is necessarily current-generation.
            let ts = self.tags.get_mut(&tag).expect("tagged entry without tag state");
            ts.live -= 1;
        }
        self.free_slot(idx);
        self.live -= 1;
        self.now = t;
        self.processed += 1;
        Some((t, event))
    }

    /// Pop the next live event only if it occurs before `horizon`.
    pub fn next_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        match self.peek_time() {
            Some(t) if t < horizon => self.next(),
            _ => None,
        }
    }
}

/// One plane of a co-simulation: handles the events addressed to it,
/// scheduling follow-ups on the shared kernel and communicating with the
/// other planes only through events and the shared world state `S`.
pub trait Component<E, S> {
    fn name(&self) -> &'static str {
        "component"
    }

    fn handle(&mut self, now: f64, event: E, kernel: &mut Kernel<E>, shared: &mut S);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_and_fifo_at_ties() {
        let mut k = Kernel::new();
        k.schedule(3.0, "c");
        k.schedule(1.0, "a1");
        k.schedule(1.0, "a2");
        k.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| k.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a1", "a2", "b", "c"]);
        assert_eq!(k.processed(), 4);
    }

    #[test]
    fn cancel_skips_timer() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.schedule(2.0, "b");
        assert_eq!(k.len(), 2);
        assert!(k.cancel(a));
        assert!(!k.cancel(a), "double cancel is a no-op");
        assert_eq!(k.len(), 1);
        let (t, e) = k.next().unwrap();
        assert_eq!((t, e), (2.0, "b"));
        assert!(k.next().is_none());
        assert_eq!(k.processed(), 1);
        assert_eq!(k.cancelled_count(), 1);
    }

    #[test]
    fn cancel_after_fire_returns_false() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.next().unwrap();
        assert!(!k.cancel(a));
    }

    #[test]
    fn invalidate_tag_kills_only_older_generation() {
        let mut k = Kernel::new();
        k.schedule_tagged(1.0, 7, "old1");
        k.schedule_tagged(2.0, 7, "old2");
        k.schedule_tagged(1.5, 8, "other-tag");
        assert_eq!(k.invalidate_tag(7), 2);
        k.schedule_tagged(3.0, 7, "new");
        let order: Vec<&str> = std::iter::from_fn(|| k.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["other-tag", "new"]);
        assert_eq!(k.cancelled_count(), 2);
        assert_eq!(k.generation(7), 1);
        assert_eq!(k.generation(8), 0);
    }

    #[test]
    fn peek_time_skips_dead_entries() {
        let mut k = Kernel::new();
        let a = k.schedule(1.0, "a");
        k.schedule(2.0, "b");
        k.cancel(a);
        assert_eq!(k.peek_time(), Some(2.0));
        // Peeking does not advance the clock or deliver.
        assert_eq!(k.now(), 0.0);
        assert_eq!(k.next().unwrap().1, "b");
    }

    #[test]
    fn clear_empties_queue() {
        let mut k = Kernel::new();
        k.schedule(1.0, 1);
        k.schedule(2.0, 2);
        k.clear();
        assert!(k.is_empty());
        assert!(k.next().is_none());
        assert_eq!(k.cancelled_count(), 2);
        // Still usable afterwards.
        k.schedule(5.0, 3);
        assert_eq!(k.next().unwrap(), (5.0, 3));
    }

    #[test]
    fn next_before_horizon() {
        let mut k = Kernel::new();
        k.schedule(1.0, "a");
        k.schedule(5.0, "b");
        assert!(k.next_before(2.0).is_some());
        assert!(k.next_before(2.0).is_none());
        assert_eq!(k.len(), 1);
    }

    #[test]
    fn fifo_property_at_equal_timestamps() {
        // Property: at any fixed timestamp, live events pop in insertion
        // order, regardless of interleaved cancels at the same time.
        let mut rng = crate::util::rng::Rng::new(99);
        let mut k = Kernel::new();
        let mut expect: Vec<(u64, usize)> = Vec::new(); // (time-as-int, payload)
        let mut cancels = Vec::new();
        for i in 0..500 {
            let t = rng.below(10) as f64;
            let id = k.schedule(t, i);
            if rng.chance(0.2) {
                cancels.push(id);
            } else {
                expect.push((t as u64, i));
            }
        }
        for id in cancels {
            assert!(k.cancel(id));
        }
        // Stable sort by time preserves insertion order within a tie —
        // exactly the kernel's contract.
        expect.sort_by_key(|&(t, _)| t);
        let got: Vec<(u64, usize)> =
            std::iter::from_fn(|| k.next().map(|(t, e)| (t as u64, e))).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn reschedule_under_new_generation_survives() {
        let mut k = Kernel::new();
        k.schedule_tagged(1.0, 3, "stale");
        k.invalidate_tag(3);
        k.invalidate_tag(3);
        k.schedule_tagged(1.0, 3, "fresh");
        assert_eq!(k.len(), 1);
        assert_eq!(k.next().unwrap().1, "fresh");
    }

    #[test]
    fn clustered_and_far_future_times_pop_in_order() {
        // Exercises all three tiers at once: a dense near cluster, a mid
        // band, and far-future outliers (the `gap_s = 1e9` idle pattern),
        // with enough entries to trigger growth rebuilds.
        let mut rng = crate::util::rng::Rng::new(7);
        let mut k = Kernel::new();
        let mut times = Vec::new();
        for i in 0..5000usize {
            let t = match i % 3 {
                0 => rng.f64() * 1e-3,        // dense cluster near zero
                1 => 1.0 + rng.f64() * 100.0, // mid band
                _ => 1.0e9 + rng.f64(),       // far future
            };
            k.schedule(t, i);
            times.push((t, i));
        }
        times.sort_by(|a, b| a.0.total_cmp(&b.0));
        let got: Vec<(f64, usize)> = std::iter::from_fn(|| k.next()).collect();
        assert_eq!(got, times);
        assert_eq!(k.processed(), 5000);
        assert!(k.is_empty());
    }

    #[test]
    fn insert_into_draining_region_keeps_order() {
        // `schedule_in(0.0)` from inside the event loop must land in the
        // already-passed wheel region and still fire after the current
        // event's equal-time peers, FIFO by seq.
        let mut k = Kernel::new();
        for i in 0..10 {
            k.schedule(1.0, i);
        }
        let (t0, first) = k.next().unwrap();
        assert_eq!((t0, first), (1.0, 0));
        k.schedule_in(0.0, 100); // same timestamp, scheduled mid-drain
        k.schedule_in(0.5, 200);
        let rest: Vec<i32> = std::iter::from_fn(|| k.next().map(|(_, e)| e)).collect();
        assert_eq!(rest, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 100, 200]);
    }

    #[test]
    fn clear_keeps_tag_generations_reset_reclaims_them() {
        let mut k = Kernel::new();
        k.schedule_tagged(1.0, 5, "a");
        k.invalidate_tag(5);
        k.clear();
        // clear(): generation history survives.
        assert_eq!(k.generation(5), 1);
        k.schedule(2.0, "x");
        k.next();
        assert!(k.now() > 0.0);
        k.reset();
        // reset(): pristine state, capacity retained.
        assert_eq!(k.generation(5), 0);
        assert_eq!(k.now(), 0.0);
        assert_eq!(k.processed(), 0);
        assert_eq!(k.cancelled_count(), 0);
        assert!(k.is_empty());
    }

    #[test]
    fn reset_kernel_matches_fresh_kernel() {
        // A warmed-then-reset kernel must deliver the exact sequence a
        // fresh one does: ordering depends only on (time, seq).
        let run = |k: &mut Kernel<usize>| -> Vec<(f64, usize)> {
            let mut rng = crate::util::rng::Rng::new(42);
            let mut ids = Vec::new();
            for i in 0..300 {
                let t = rng.f64() * 50.0;
                ids.push(k.schedule(t, i));
            }
            for (j, id) in ids.iter().enumerate() {
                if j % 7 == 0 {
                    k.cancel(*id);
                }
            }
            std::iter::from_fn(|| k.next()).collect()
        };
        let mut fresh = Kernel::new();
        let expect = run(&mut fresh);
        let mut warmed = Kernel::new();
        let _ = run(&mut warmed); // warm the slab and wheel
        warmed.reset();
        assert_eq!(run(&mut warmed), expect);
    }
}
