//! Hand-rolled CLI argument parser (no `clap` in the offline environment).
//!
//! Grammar: `hflop <subcommand> [--key value | --flag] [positional..]`.
//! Typed accessors with defaults; `--help` rendering is the caller's job
//! (`main.rs` owns the usage strings).

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    /// Every `--key value` occurrence in command-line order. `options`
    /// keeps only the last value per key; repeatable options (such as
    /// `experiment --set k=v --set k2=v2`) read this instead.
    pub all_options: Vec<(String, String)>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("option --{0} expects a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
}

/// Parse: `--key value` when the next token is not another option, else a
/// boolean flag. First bare token is the subcommand.
pub fn parse(argv: &[String]) -> Result<Args, CliError> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(tok) = it.next() {
        if let Some(name) = tok.strip_prefix("--") {
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let value = it.next().unwrap().clone();
                    args.options.insert(name.to_string(), value.clone());
                    args.all_options.push((name.to_string(), value));
                }
                _ => args.flags.push(name.to_string()),
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(tok.clone());
        } else {
            args.positional.push(tok.clone());
        }
    }
    Ok(args)
}

impl Args {
    pub fn from_env() -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        parse(&argv)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// All values given for a repeatable option, in command-line order.
    pub fn all(&self, key: &str) -> Vec<&str> {
        self.all_options
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn subcommand_and_options() {
        // Schema-light grammar: a flag followed by a bare token would be
        // read as `--key value`, so flags go last (documented in --help).
        let a = parse(&argv("solve input.toml --n 100 --m 8 --exact")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("solve"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 100);
        assert_eq!(a.usize_or("m", 0).unwrap(), 8);
        assert!(a.has_flag("exact"));
        assert_eq!(a.positional, vec!["input.toml"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv("train")).unwrap();
        assert_eq!(a.usize_or("rounds", 100).unwrap(), 100);
        assert_eq!(a.str_or("variant", "paper"), "paper");
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&argv("x --verbose --seed 7")).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = parse(&argv("x --n abc")).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn repeated_options_all_preserved() {
        let a = parse(&argv("experiment fig7 --set a=1 --set b=2 --set a=3")).unwrap();
        // Map keeps the last occurrence; `all` keeps every one in order.
        assert_eq!(a.str_or("set", ""), "a=3");
        assert_eq!(a.all("set"), vec!["a=1", "b=2", "a=3"]);
        assert!(a.all("nope").is_empty());
    }

    #[test]
    fn trailing_flag_and_empty() {
        let a = parse(&argv("x --fast")).unwrap();
        assert!(a.has_flag("fast"));
        assert!(parse(&[]).unwrap().subcommand.is_none());
    }
}
