//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `bench(name, iters, f)` reports mean / std / min per iteration;
//! `bench_n` auto-scales iteration counts to a time budget. All benches
//! print a aligned `name: mean ± std (min)` line so `cargo bench` output
//! is diffable and EXPERIMENTS.md can quote it directly.

use std::time::Instant;

/// CI smoke mode: `HFLOP_BENCH_SMOKE=1` clamps every bench to a single
/// iteration and skips warmup, so workflows can verify the harnesses
/// still build and run without paying for full sweeps. Delegates to
/// `hflop::util::smoke_mode` — the registry experiments obey the same
/// knob, so one environment variable scales the whole CI smoke budget.
pub fn smoke() -> bool {
    hflop::util::smoke_mode()
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_time(self.mean_s),
            format!("±{}", fmt_time(self.std_s)),
            fmt_time(self.min_s),
            self.iters
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` `iters` times, timing each run.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    let iters = if smoke() { 1 } else { iters };
    // Warmup (skipped in smoke mode).
    if !smoke() {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    r.report();
    r
}

/// Run `f` repeatedly until ~`budget_s` seconds elapse (at least 3 iters).
pub fn bench_auto<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    if smoke() {
        return bench(name, 1, f);
    }
    let t0 = Instant::now();
    std::hint::black_box(f());
    let per = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / per) as usize).clamp(3, 10_000);
    bench(name, iters, f)
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "std", "min"
    );
}
