//! Bench: FL round engine — FedAvg over paper-sized parameter blocks,
//! one full aggregation round (mock runtime to isolate coordination
//! overhead from model execution), and the continual window machinery.
//! The paper's system claim is that orchestration is not the bottleneck;
//! this bench quantifies L3 overhead per round.

mod bench_common;
use bench_common::{bench, bench_auto, header};

use hflop::data::window::{ClientData, ContinualWindow, WindowSpec};
use hflop::fl::{fedavg, Client, ContinualHfl, FlConfig, Hierarchy, MockRuntime, ModelRuntime};
use hflop::util::rng::Rng;

fn main() {
    header("FedAvg over paper-sized blocks (149,505 f32 params)");
    let mut rng = Rng::new(2);
    let blocks: Vec<Vec<f32>> = (0..20)
        .map(|_| (0..149_505).map(|_| rng.normal() as f32).collect())
        .collect();
    for k in [2usize, 5, 20] {
        bench_auto(&format!("fl/fedavg k={k}"), 1.0, || {
            let refs: Vec<(&[f32], f64)> =
                blocks[..k].iter().map(|b| (b.as_slice(), 1.0)).collect();
            fedavg(&refs)
        });
    }

    header("Coordination overhead: full aggregation round (mock model)");
    let rt = MockRuntime::new(12, 16);
    for n_clients in [10usize, 50, 200] {
        let raw: Vec<f32> = (0..6000).map(|i| ((i as f32) * 0.01).sin()).collect();
        let clients: Vec<Client> = (0..n_clients)
            .map(|id| {
                Client::new(
                    id,
                    ClientData::new(&raw, WindowSpec { seq_len: 12, horizon: 1 }, (0, 4000)),
                    9,
                )
            })
            .collect();
        let hierarchy = Hierarchy {
            clusters: (0..4)
                .map(|j| hflop::fl::Cluster {
                    edge_id: j,
                    members: (0..n_clients).filter(|i| i % 4 == j).collect(),
                })
                .collect(),
            flat: false,
        };
        let window = ContinualWindow::new(4000, 1000, 0, 6000);
        let fl = FlConfig {
            epochs: 1,
            batches_per_epoch: 2,
            l: 2,
            lr: 0.01,
            rounds: 1,
            eval_every: 1,
        };
        let mut sys = ContinualHfl::new(
            &rt,
            hierarchy,
            clients,
            window,
            fl,
            vec![0.0; rt.n_params()],
            None,
        );
        let mut round = 0usize;
        bench(&format!("fl/round n_clients={n_clients}"), 5, || {
            let r = sys.step_round(round).unwrap();
            round += 1;
            r
        });
    }

    header("Continual window machinery");
    let raw: Vec<f32> = (0..40_000).map(|i| ((i as f32) * 0.01).cos()).collect();
    let cd = ClientData::new(&raw, WindowSpec { seq_len: 12, horizon: 1 }, (0, 30_000));
    let mut rng2 = Rng::new(3);
    bench_auto("data/sample_batch b=16", 0.5, || {
        cd.sample_batch((0, 30_000), 16, &mut rng2)
    });
    bench_auto("data/windows 6048-span", 0.5, || cd.windows((0, 6048)));
}
