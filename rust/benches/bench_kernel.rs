//! Bench: the event-kernel hot path, in events/sec.
//!
//! Three workloads, run against both the production calendar-queue
//! [`hflop::sim::Kernel`] and the frozen binary-heap oracle
//! [`hflop::sim::oracle::HeapKernel`] (the exact queue the repo used to
//! run on):
//!
//! 1. `churn` — pure schedule→fire at a large resident set (the classic
//!    hold-model queue benchmark): pop one event, schedule its
//!    replacement a uniform offset ahead.
//! 2. `tagged-cancel` — schedule/fire mixed with handle cancels and
//!    periodic tag invalidations, the control-plane revocation pattern.
//!    The oracle's O(len) cancel scan makes this its worst case, so the
//!    oracle runs a smaller op count and throughput is normalized.
//! 3. `cosim/interference` — end-to-end events/sec of a full
//!    interference-preset co-simulation on the production kernel (no
//!    oracle run: it is not wired into the production path).
//!
//! Emits `BENCH_kernel.json` (schema-versioned) so the perf trajectory
//! accumulates data points in CI; `BENCHMARKS.md` at the repo root
//! explains how to read it. `HFLOP_BENCH_SMOKE=1` shrinks every workload
//! so CI can verify the harness cheaply.

mod bench_common;
use bench_common::{bench, header, smoke};

use hflop::experiments::interference::{run_with_kernel, InterferenceConfig, Preset};
use hflop::experiments::scenario::{Scenario, ScenarioConfig};
use hflop::metrics::export::SCHEMA_VERSION;
use hflop::sim::oracle::HeapKernel;
use hflop::sim::Kernel;
use hflop::util::json::Json;
use hflop::util::rng::Rng;
use hflop::util::stats::geomean;

/// Pure schedule→fire churn: `events` pop+reschedule pairs over a
/// resident set of `resident` pending timers. Returns ops performed
/// (one schedule + one fire per event).
macro_rules! churn {
    ($mk:expr, $resident:expr, $events:expr) => {{
        let mut k = $mk;
        let mut rng = Rng::new(0x6368_7572_6e21);
        for i in 0..$resident {
            k.schedule(rng.f64() * 10.0, i as u32);
        }
        let mut fired = 0u64;
        while fired < $events {
            let (t, _) = k.next().expect("resident set never empties");
            fired += 1;
            k.schedule(t + rng.f64() * 10.0, fired as u32);
        }
        std::hint::black_box(k.len());
        2 * fired
    }};
}

/// Schedule/fire churn with handle cancels and periodic tag
/// invalidations. Returns total ops (schedules + fires + cancels +
/// invalidations), the unit the events/sec figures normalize over.
macro_rules! cancel_churn {
    ($mk:expr, $resident:expr, $target_ops:expr) => {{
        let mut k = $mk;
        let mut rng = Rng::new(0x6b69_6c6c);
        let mut ids = std::collections::VecDeque::new();
        let mut ops = 0u64;
        for i in 0..$resident {
            ids.push_back(k.schedule_tagged(rng.f64() * 10.0, i % 16, i as u32));
            ops += 1;
        }
        let mut i: u32 = 0;
        while ops < $target_ops {
            let t = k.now() + rng.f64() * 10.0;
            ids.push_back(k.schedule_tagged(t, (i % 16) as u64, i));
            ops += 1;
            // Retire the oldest handle; cancel half of them (the other
            // half fire or die via tag invalidation).
            if let Some(id) = ids.pop_front() {
                if i % 2 == 0 {
                    k.cancel(id);
                    ops += 1;
                }
            }
            if i % 4096 == 0 {
                k.invalidate_tag(rng.below(16) as u64);
                ops += 1;
            }
            if k.next().is_some() {
                ops += 1;
            }
            i += 1;
        }
        std::hint::black_box(k.len());
        ops
    }};
}

fn workload_json(name: &str, events: u64, wall_s: f64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("events", Json::Num(events as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("events_per_sec", Json::Num(events as f64 / wall_s.max(1e-12))),
    ])
}

fn main() {
    let smoke = smoke();

    // Workload sizes. Full mode drives ~10M events of pure churn — the
    // trajectory point the acceptance criterion tracks.
    let (resident, churn_events) = if smoke {
        (4_096u64, 50_000u64)
    } else {
        (65_536, 10_000_000)
    };
    let (cc_resident, cc_new_ops, cc_old_ops) = if smoke {
        (512u64, 20_000u64, 10_000u64)
    } else {
        (8_192, 2_000_000, 200_000)
    };

    header(&format!(
        "event kernel: calendar queue vs binary-heap oracle ({} churn events, resident {})",
        churn_events, resident
    ));

    // -- 1. pure schedule→fire churn -------------------------------------
    let mut ops_new = 0u64;
    let churn_new = bench("kernel/churn/calendar", 1, || {
        ops_new = churn!(Kernel::new(), resident, churn_events);
    });
    let mut ops_old = 0u64;
    let churn_old = bench("kernel/churn/heap-oracle", 1, || {
        ops_old = churn!(HeapKernel::new(), resident, churn_events);
    });
    let churn_evps_new = ops_new as f64 / churn_new.mean_s.max(1e-12);
    let churn_evps_old = ops_old as f64 / churn_old.mean_s.max(1e-12);
    let churn_speedup = churn_evps_new / churn_evps_old.max(1e-12);
    println!(
        "  -> churn: {:.2e} ev/s calendar vs {:.2e} ev/s heap ({churn_speedup:.2}x)",
        churn_evps_new, churn_evps_old
    );

    // -- 2. tagged-cancel churn -------------------------------------------
    let mut cc_ops_new = 0u64;
    let cc_new = bench("kernel/tagged-cancel/calendar", 1, || {
        cc_ops_new = cancel_churn!(Kernel::new(), cc_resident, cc_new_ops);
    });
    let mut cc_ops_old = 0u64;
    let cc_old = bench("kernel/tagged-cancel/heap-oracle", 1, || {
        cc_ops_old = cancel_churn!(HeapKernel::new(), cc_resident, cc_old_ops);
    });
    let cc_evps_new = cc_ops_new as f64 / cc_new.mean_s.max(1e-12);
    let cc_evps_old = cc_ops_old as f64 / cc_old.mean_s.max(1e-12);
    let cc_speedup = cc_evps_new / cc_evps_old.max(1e-12);
    println!(
        "  -> tagged-cancel: {:.2e} ops/s calendar vs {:.2e} ops/s heap ({cc_speedup:.2}x)",
        cc_evps_new, cc_evps_old
    );

    // -- 3. end-to-end co-simulation on the production kernel --------------
    let sc = Scenario::build(ScenarioConfig {
        n_clients: if smoke { 12 } else { 20 },
        n_edges: if smoke { 3 } else { 4 },
        weeks: 5,
        balanced_clients: false,
        ..Default::default()
    })
    .expect("bench scenario");
    let cfg = InterferenceConfig {
        preset: Preset::DiurnalSurge,
        duration_s: if smoke { 20.0 } else { 240.0 },
        record_trace: false,
        ..Default::default()
    };
    let mut kernel = Some(Kernel::new());
    let mut cosim_events = 0u64;
    let cosim = bench("cosim/interference-e2e", if smoke { 1 } else { 3 }, || {
        let (out, k) =
            run_with_kernel(&sc, &cfg, kernel.take().expect("kernel threaded")).expect("cosim run");
        cosim_events = out.events_processed;
        kernel = Some(k);
        std::hint::black_box(out.serving.total());
    });
    let cosim_evps = cosim_events as f64 / cosim.mean_s.max(1e-12);
    println!("  -> cosim: {cosim_events} kernel events at {cosim_evps:.2e} ev/s");

    let speedup_geomean = geomean(&[churn_speedup, cc_speedup]);
    println!("  -> geomean kernel speedup vs heap oracle: {speedup_geomean:.2}x");

    let artifact = Json::obj(vec![
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("smoke", Json::Bool(smoke)),
        (
            "calendar",
            Json::Arr(vec![
                workload_json("churn", ops_new, churn_new.mean_s),
                workload_json("tagged-cancel", cc_ops_new, cc_new.mean_s),
            ]),
        ),
        (
            "heap_oracle",
            Json::Arr(vec![
                workload_json("churn", ops_old, churn_old.mean_s),
                workload_json("tagged-cancel", cc_ops_old, cc_old.mean_s),
            ]),
        ),
        (
            "speedup",
            Json::obj(vec![
                ("churn", Json::Num(churn_speedup)),
                ("tagged_cancel", Json::Num(cc_speedup)),
                ("geomean", Json::Num(speedup_geomean)),
            ]),
        ),
        ("cosim", workload_json("interference-e2e", cosim_events, cosim.mean_s)),
    ]);
    std::fs::write("BENCH_kernel.json", artifact.to_pretty()).expect("write BENCH_kernel.json");
    println!("  -> wrote BENCH_kernel.json");
}
