//! Bench: Fig. 2 — exact HFLOP solve time vs instance size, the
//! LP-simplex microbenchmark, the exact-vs-heuristic ablation, and the
//! sharded region-parallel scale trajectory (up to n = 1M devices).
//! Regenerates the data behind paper Fig. 2 (see EXPERIMENTS.md) and
//! writes the schema-versioned `BENCH_solver.json` artifact that CI
//! uploads on every run (BENCHMARKS.md tracks the trajectory).

mod bench_common;
use bench_common::{bench, bench_auto, header, smoke};

use hflop::hflop::{InstanceBuilder, SparseInstance};
use hflop::metrics::export::SCHEMA_VERSION;
use hflop::solver::greedy::greedy;
use hflop::solver::local_search::{local_search, LocalSearchOptions, LsMode};
use hflop::solver::milp::build_relaxation;
use hflop::solver::{aggregated_lp_bound, branch_and_bound, solve_sparse, BbOptions, SolveOptions};
use hflop::util::json::Json;

fn main() {
    let smoke = smoke();

    header("Fig. 2: exact solve time vs instance size (B&B + simplex, 1 core)");
    let exact_points: &[(usize, usize)] = if smoke {
        &[(25, 4), (50, 4)]
    } else {
        &[(25, 4), (50, 4), (100, 6), (200, 8), (400, 10)]
    };
    for &(n, m) in exact_points {
        let insts: Vec<_> = (0..3)
            .map(|r| InstanceBuilder::unit_cost(n, m, 7000 + r).build())
            .collect();
        let mut i = 0;
        bench(&format!("fig2/solve_exact n={n} m={m}"), 3, || {
            let inst = &insts[i % insts.len()];
            i += 1;
            branch_and_bound(inst, &BbOptions { time_limit_s: Some(30.0), ..Default::default() })
        });
    }

    header("LP relaxation microbench (simplex hot path)");
    for &(n, m) in &[(50usize, 5usize), (100, 8), (200, 10)] {
        let inst = InstanceBuilder::unit_cost(n, m, 11).build();
        bench_auto(&format!("lp/relaxation n={n} m={m}"), 1.0, || {
            build_relaxation(&inst, &[], n * m <= 400).solve()
        });
    }

    header("Heuristics (large-instance path, §IV-C)");
    let heur_points: &[(usize, usize)] =
        if smoke { &[(200, 10)] } else { &[(200, 10), (500, 20), (1000, 32)] };
    for &(n, m) in heur_points {
        let inst = InstanceBuilder::unit_cost(n, m, 13).build();
        bench(&format!("heuristic/greedy n={n} m={m}"), 3, || greedy(&inst));
        bench(&format!("heuristic/local_search n={n} m={m}"), 3, || {
            local_search(&inst, &LocalSearchOptions::default())
        });
    }

    // Flat-core scaling point: the incremental O(1)-delta engine against
    // the pre-refactor completion baseline (full re-complete + re-score
    // per candidate) on the same n=500/m=20 instance. The two local
    // optima may differ slightly; both costs are printed so quality and
    // speed are judged together. Record the numbers in CHANGES.md.
    if !smoke {
        header("core refactor: completion baseline vs incremental (n=500, m=20)");
        let inst = InstanceBuilder::unit_cost(500, 20, 17).build();
        let completion = LocalSearchOptions { mode: LsMode::Completion, ..Default::default() };
        let incremental = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
        bench("ls/completion(full-rescore) n=500 m=20", 3, || {
            local_search(&inst, &completion)
        });
        bench("ls/incremental(delta-eval) n=500 m=20", 3, || {
            local_search(&inst, &incremental)
        });
        let c = local_search(&inst, &completion);
        let i = local_search(&inst, &incremental);
        println!(
            "ls quality: completion cost {:.3} ({} moves) | incremental cost {:.3} ({} moves)",
            c.cost, c.moves, i.cost, i.moves
        );
    }

    // -- sharded region-parallel scale trajectory --------------------------
    // One solve per point (the solve's own wall clock is the measurement;
    // a warmup at n=1M would double the bench cost for nothing). Every
    // point reports the Eq. 1 cost, the aggregated-LP lower bound and the
    // relative gap, plus the candidate-structure memory against the dense
    // matrix it replaces — the sublinear-memory claim made checkable.
    header("sharded scale: region-parallel sparse solves (cost vs aggregated-LP bound)");
    let scale_points: &[(usize, usize, usize)] = if smoke {
        &[(2_000, 16, 8), (5_000, 32, 8)]
    } else {
        &[(2_000, 16, 8), (5_000, 32, 8), (100_000, 128, 12), (1_000_000, 512, 12)]
    };
    let mut events = Vec::new();
    for &(n, m, cand_k) in scale_points {
        let t0 = std::time::Instant::now();
        let sp = SparseInstance::clustered(n, m, 4242, cand_k);
        let build_s = t0.elapsed().as_secs_f64();
        let mut opts = SolveOptions::sharded();
        opts.shard.root_seed = 4242;
        let out = solve_sparse(&sp, &opts).expect("sharded solve");
        let stats = out.sharded.expect("sharded stats");
        let bound = aggregated_lp_bound(&sp);
        let cost = out.solution.cost;
        let gap = if bound > 0.0 { (cost - bound) / bound } else { 0.0 };
        let cand_mb = sp.candidate_bytes() as f64 / 1e6;
        let dense_mb = sp.dense_equiv_bytes() as f64 / 1e6;
        println!(
            "sharded n={n} m={m} k={cand_k}: cost {cost:.1} bound {bound:.1} \
             gap {:.2}% | build {build_s:.2}s solve {:.2}s | {} regions, \
             {} repairs, {} rescued | mem {cand_mb:.1} MB vs dense {dense_mb:.1} MB",
            gap * 100.0,
            out.solution.wall_s,
            stats.regions,
            stats.repair_moves,
            stats.rescued,
        );
        events.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("m", Json::Num(m as f64)),
            ("cand_k", Json::Num(cand_k as f64)),
            ("cost", Json::Num(cost)),
            ("bound", Json::Num(bound)),
            ("gap", Json::Num(gap)),
            ("wall_s", Json::Num(out.solution.wall_s)),
            ("build_s", Json::Num(build_s)),
            ("regions", Json::Num(stats.regions as f64)),
            ("repair_moves", Json::Num(stats.repair_moves as f64)),
            ("rescued", Json::Num(stats.rescued as f64)),
            ("candidate_mb", Json::Num(cand_mb)),
            ("dense_equiv_mb", Json::Num(dense_mb)),
        ]));
    }

    // Worker-count determinism spot check at the smallest scale point:
    // the same root seed must give a bit-identical solution at 1 and 8
    // workers (the full property test lives in tests/sharded_equivalence).
    let sp = SparseInstance::clustered(2_000, 16, 4242, 8);
    let solve_at = |workers: usize| {
        let mut opts = SolveOptions::sharded();
        opts.shard.root_seed = 4242;
        opts.shard.workers = workers;
        solve_sparse(&sp, &opts).expect("sharded solve").solution
    };
    let a = solve_at(1);
    let b = solve_at(8);
    let identical =
        a.cost.to_bits() == b.cost.to_bits() && a.assignment.assign == b.assignment.assign;
    assert!(identical, "sharded solve must be worker-count independent");
    println!("  -> worker determinism: 1 vs 8 workers bit-identical = {identical}");

    let artifact = Json::obj(vec![
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("smoke", Json::Bool(smoke)),
        ("events", Json::Arr(events)),
        (
            "determinism",
            Json::obj(vec![
                ("point", Json::Str("n=2000 m=16 cand_k=8".into())),
                ("workers_1_vs_8_identical", Json::Bool(identical)),
            ]),
        ),
        (
            "note",
            Json::Str("sharded solver scale trajectory; see BENCHMARKS.md".into()),
        ),
    ]);
    std::fs::write("BENCH_solver.json", artifact.to_pretty()).expect("write BENCH_solver.json");
    println!("  -> wrote BENCH_solver.json");
}
