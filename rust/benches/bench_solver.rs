//! Bench: Fig. 2 — exact HFLOP solve time vs instance size, plus the
//! LP-simplex microbenchmark and the exact-vs-heuristic ablation.
//! Regenerates the data behind paper Fig. 2 (see EXPERIMENTS.md).

mod bench_common;
use bench_common::{bench, bench_auto, header};

use hflop::hflop::InstanceBuilder;
use hflop::solver::greedy::greedy;
use hflop::solver::local_search::{local_search, LocalSearchOptions, LsMode};
use hflop::solver::milp::build_relaxation;
use hflop::solver::{branch_and_bound, BbOptions};

fn main() {
    header("Fig. 2: exact solve time vs instance size (B&B + simplex, 1 core)");
    for &(n, m) in &[(25usize, 4usize), (50, 4), (100, 6), (200, 8), (400, 10)] {
        let insts: Vec<_> = (0..3)
            .map(|r| InstanceBuilder::unit_cost(n, m, 7000 + r).build())
            .collect();
        let mut i = 0;
        bench(&format!("fig2/solve_exact n={n} m={m}"), 3, || {
            let inst = &insts[i % insts.len()];
            i += 1;
            branch_and_bound(inst, &BbOptions { time_limit_s: 30.0, ..Default::default() })
        });
    }

    header("LP relaxation microbench (simplex hot path)");
    for &(n, m) in &[(50usize, 5usize), (100, 8), (200, 10)] {
        let inst = InstanceBuilder::unit_cost(n, m, 11).build();
        bench_auto(&format!("lp/relaxation n={n} m={m}"), 1.0, || {
            build_relaxation(&inst, &[], n * m <= 400).solve()
        });
    }

    header("Heuristics (large-instance path, §IV-C)");
    for &(n, m) in &[(200usize, 10usize), (500, 20), (1000, 32)] {
        let inst = InstanceBuilder::unit_cost(n, m, 13).build();
        bench(&format!("heuristic/greedy n={n} m={m}"), 3, || greedy(&inst));
        bench(&format!("heuristic/local_search n={n} m={m}"), 3, || {
            local_search(&inst, &LocalSearchOptions::default())
        });
    }

    // Flat-core scaling point: the incremental O(1)-delta engine against
    // the pre-refactor completion baseline (full re-complete + re-score
    // per candidate) on the same n=500/m=20 instance. The two local
    // optima may differ slightly; both costs are printed so quality and
    // speed are judged together. Record the numbers in CHANGES.md.
    header("core refactor: completion baseline vs incremental (n=500, m=20)");
    let inst = InstanceBuilder::unit_cost(500, 20, 17).build();
    let completion = LocalSearchOptions { mode: LsMode::Completion, ..Default::default() };
    let incremental = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
    bench("ls/completion(full-rescore) n=500 m=20", 3, || {
        local_search(&inst, &completion)
    });
    bench("ls/incremental(delta-eval) n=500 m=20", 3, || {
        local_search(&inst, &incremental)
    });
    let c = local_search(&inst, &completion);
    let i = local_search(&inst, &incremental);
    println!(
        "ls quality: completion cost {:.3} ({} moves) | incremental cost {:.3} ({} moves)",
        c.cost, c.moves, i.cost, i.moves
    );
}
