//! Bench: the PJRT hot path — model execution through the AOT artifacts.
//! This is the L3 serving/training critical path: predict (B=1), batched
//! predict (B=8), train_step, eval, plus the dynamic batcher overhead on
//! top of raw execution. Requires `make artifacts`.

mod bench_common;
use bench_common::{bench_auto, header};

use hflop::inference::serving::{BatchingServer, InferenceRequest};
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::util::rng::Rng;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        println!("artifacts not built — run `make artifacts` first; skipping runtime bench");
        return;
    };

    for variant in ["small", "paper"] {
        let engine = Engine::new(&manifest, variant, Preload::All).expect("engine");
        let v = engine.variant().clone();
        let params = manifest.load_init_params(&v).expect("params");
        let mut rng = Rng::new(1);

        header(&format!(
            "PJRT hot path — variant '{variant}' (GRU h={} L={}, {} params)",
            v.hidden, v.layers, v.param_count
        ));

        let x1: Vec<f32> = (0..v.seq_len * v.in_dim).map(|_| rng.normal() as f32).collect();
        bench_auto(&format!("runtime/{variant}/predict_b1"), 2.0, || {
            engine.predict(&params, &x1).unwrap()
        });

        let xb: Vec<f32> = (0..v.serve_batch * v.seq_len * v.in_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let rb = bench_auto(&format!("runtime/{variant}/predict_b8"), 2.0, || {
            engine.predict_batch(&params, &xb).unwrap()
        });
        println!(
            "  -> batched throughput {:.0} req/s",
            v.serve_batch as f64 / rb.mean_s
        );

        let xt: Vec<f32> = (0..v.train_batch * v.seq_len * v.in_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let yt: Vec<f32> = (0..v.train_batch * v.out_dim).map(|_| rng.normal() as f32).collect();
        let rt = bench_auto(&format!("runtime/{variant}/train_step"), 2.0, || {
            engine.train_step(&params, &xt, &yt, 1e-3).unwrap()
        });
        println!(
            "  -> {:.0} samples/s training throughput",
            v.train_batch as f64 / rt.mean_s
        );

        let xe: Vec<f32> = (0..v.eval_batch * v.seq_len * v.in_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let ye: Vec<f32> = (0..v.eval_batch * v.out_dim).map(|_| rng.normal() as f32).collect();
        bench_auto(&format!("runtime/{variant}/eval_b{}", v.eval_batch), 2.0, || {
            engine.eval_mse(&params, &xe, &ye).unwrap()
        });

        // Batcher overhead: full submit->flush cycle vs raw predict_batch.
        let mut server = BatchingServer::new(&engine, params.clone());
        let windows: Vec<Vec<f32>> = (0..v.serve_batch)
            .map(|_| (0..v.seq_len * v.in_dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut id = 0u64;
        let clock = hflop::util::WallClock::start();
        let rs = bench_auto(&format!("runtime/{variant}/batcher_cycle_b8"), 2.0, || {
            let mut out = Vec::new();
            for w in &windows {
                id += 1;
                out = server
                    .submit(InferenceRequest { id, window: w.clone() }, clock.elapsed_s())
                    .unwrap();
            }
            out
        });
        println!(
            "  -> batcher overhead per request: {:.1} µs (cycle {:.3} ms vs raw {:.3} ms)",
            (rs.mean_s - rb.mean_s).max(0.0) / v.serve_batch as f64 * 1e6,
            rs.mean_s * 1e3,
            rb.mean_s * 1e3,
        );
    }
}
