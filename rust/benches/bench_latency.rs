//! Bench: Fig. 7 / Fig. 8 — the inference-serving DES. Reports both the
//! experiment outputs (latency means per setup, crossover speedup) and
//! the simulator's own event throughput (events/s), which is the L3
//! bottleneck for large sweeps.

mod bench_common;
use bench_common::{bench, header};

use hflop::experiments::{fig7, fig8, Scenario, ScenarioConfig};
use hflop::inference::simulation::{simulate, ServingConfig};
use hflop::inference::LatencyModel;

fn main() {
    let sc = Scenario::build(ScenarioConfig {
        n_clients: 20,
        n_edges: 4,
        weeks: 5,
        balanced_clients: false,
        ..Default::default()
    })
    .expect("scenario");

    header("Fig. 7: three-setup serving simulation (120 simulated seconds)");
    let mut last = None;
    bench("fig7/run_all_setups", 3, || {
        let r = fig7::run(&sc, &fig7::Fig7Config::default());
        last = Some((
            r.flat.latency.mean(),
            r.location.latency.mean(),
            r.hflop.latency.mean(),
        ));
        r
    });
    if let Some((f, l, h)) = last {
        println!(
            "  -> means: flat {f:.2} ms | hier {l:.2} ms | hflop {h:.2} ms   (paper: 79.07 / 17.72 / 9.89)"
        );
    }

    header("Fig. 8: speedup sweep (both panels)");
    bench("fig8/panel_a_sweep", 2, || {
        fig8::run(&sc, &fig8::Fig8Config { duration_s: 30.0, ..Default::default() })
    });
    let mut cx = None;
    bench("fig8/panel_b_sweep", 2, || {
        let rows = fig8::run(
            &sc,
            &fig8::Fig8Config { duration_s: 30.0, lambda_scale: 10.0, ..Default::default() },
        );
        cx = fig8::crossover(&rows);
        rows
    });
    println!("  -> fig8b crossover: {cx:?} (paper: 0.1425)");

    header("DES core throughput");
    for &(devices, rate) in &[(20usize, 50.0f64), (100, 50.0), (100, 200.0)] {
        let cfg = ServingConfig {
            assign: (0..devices).map(|i| Some(i % 4)).collect(),
            lambda: vec![rate; devices],
            capacity: vec![rate * devices as f64; 4],
            latency: LatencyModel::default(),
            duration_s: 10.0,
            queue_window_s: 0.25,
            seed: 3,
        };
        let events = (devices as f64 * rate * 10.0) as u64;
        let r = bench(
            &format!("des/simulate dev={devices} rate={rate} (~{events} req)"),
            3,
            || simulate(&cfg),
        );
        println!("  -> ~{:.2} M requests/s simulated", events as f64 / r.mean_s / 1e6);
    }
}
