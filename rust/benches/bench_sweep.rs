//! Bench: the scenario-sweep engine — serial loop vs the scoped worker
//! pool on the interference grid. Emits `BENCH_sweep.json` (matrix +
//! timing) so the perf trajectory accumulates data points in CI, and
//! prints the speedup the acceptance criterion tracks: the 8-worker run
//! of the interference presets must complete in measurably less
//! wall-clock than the serial loop.
//!
//! `HFLOP_BENCH_SMOKE=1` swaps in the smoke grid (small world, short
//! horizon) so CI can verify the harness cheaply.

mod bench_common;
use bench_common::{bench, header, smoke};

use hflop::experiments::sweep::{run_grid, SweepGrid};
use hflop::metrics::export::SCHEMA_VERSION;
use hflop::util::json::Json;
use hflop::util::pool;

fn main() {
    let smoke = smoke();
    let grid = if smoke { SweepGrid::smoke(2026) } else { SweepGrid::interference(2026) };
    let workers = pool::default_workers().clamp(2, 8);

    header(&format!(
        "sweep engine: '{}' grid, {} cells, serial vs {} workers",
        grid.name,
        grid.n_cells(),
        workers
    ));

    let mut matrix = None;
    let serial = bench("sweep/serial", 1, || {
        run_grid(&grid, 1).expect("serial sweep")
    });
    let parallel = bench(&format!("sweep/{workers}-workers"), 1, || {
        let m = run_grid(&grid, workers).expect("parallel sweep");
        matrix = Some(m);
    });
    let matrix = matrix.expect("parallel sweep ran");
    let speedup = serial.mean_s / parallel.mean_s.max(1e-9);
    println!(
        "  -> speedup {speedup:.2}x over {} cells (total cell work {:.2}s)",
        matrix.cells.len(),
        matrix.total_cell_wall_s()
    );

    let artifact = Json::obj(vec![
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("matrix", matrix.to_json()),
        (
            "timing",
            Json::obj(vec![
                ("workers", Json::Num(workers as f64)),
                ("serial_wall_s", Json::Num(serial.mean_s)),
                ("parallel_wall_s", Json::Num(parallel.mean_s)),
                ("speedup", Json::Num(speedup)),
                ("total_cell_wall_s", Json::Num(matrix.total_cell_wall_s())),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_sweep.json", artifact.to_pretty()).expect("write BENCH_sweep.json");
    println!("  -> wrote BENCH_sweep.json");
}
