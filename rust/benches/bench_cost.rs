//! Bench: Fig. 9 — the cost-savings sweep (solve + cost accounting per
//! density) and the closed-form cost model itself.

mod bench_common;
use bench_common::{bench, bench_auto, header};

use hflop::experiments::fig9;
use hflop::hflop::InstanceBuilder;
use hflop::metrics::cost::{flat_fl_bytes, hfl_bytes};
use hflop::solver::{self, SolveOptions};

fn main() {
    header("Fig. 9: density sweep (200 devices, reps=3)");
    let mut rows_out = None;
    bench("fig9/full_sweep n=200", 2, || {
        let cfg = fig9::Fig9Config { n_devices: 200, reps: 3, ..Default::default() };
        let rows = fig9::run(&cfg).expect("fig9");
        rows_out = Some(rows.clone());
        rows
    });
    if let Some(rows) = rows_out {
        for r in rows {
            println!(
                "  -> m={:<3} hflop {:.1}% ± {:.1} | uncap {:.1}% ± {:.1}",
                r.m, r.hflop_savings_pct, r.hflop_ci95, r.uncap_savings_pct, r.uncap_ci95
            );
        }
    }

    header("absolute reference (paper: 2.37 / 0.53 / 0.24 GB)");
    bench("fig9/absolute_reference", 3, || fig9::absolute_reference(5).unwrap());
    let (f, c, u) = fig9::absolute_reference(5).unwrap();
    println!("  -> flat {f:.2} GB | hflop {c:.2} GB | uncap {u:.2} GB");

    header("cost-model microbench");
    let inst = InstanceBuilder::unit_cost(500, 20, 3).build();
    let sol = solver::solve(&inst, &SolveOptions::heuristic()).unwrap().assignment;
    bench_auto("cost/hfl_bytes n=500 m=20", 0.5, || {
        hfl_bytes(&inst, &sol, 100, 598_020)
    });
    bench_auto("cost/flat_fl_bytes", 0.2, || flat_fl_bytes(500, 100, 598_020));
}
