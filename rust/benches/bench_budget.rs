//! Bench: budget-governed re-orchestration (DESIGN.md §11) — governed
//! co-sim cells/sec (unbudgeted oracle vs hard-capped governor) plus
//! the measured spend / deferral / regret numbers for one cell pair.
//! Writes the schema-versioned `BENCH_budget.json` artifact that CI
//! uploads on every run (BENCHMARKS.md tracks the trajectory).

mod bench_common;
use bench_common::{bench, header, smoke};

use hflop::experiments::budget::{run_cell, BudgetCellConfig};
use hflop::experiments::scenario::{Scenario, ScenarioConfig};
use hflop::metrics::export::SCHEMA_VERSION;
use hflop::orchestrator::BudgetPolicy;
use hflop::sim::Kernel;
use hflop::util::json::Json;

const CAP_BYTES: u64 = 2_000_000;

fn main() {
    let smoke = smoke();

    header("Budget control plane: governed co-sim cells (oracle vs hard cap)");
    let points: &[(usize, usize, f64)] = if smoke {
        &[(12, 3, 60.0)]
    } else {
        // (clients, edges, horizon s); the second point doubles the world.
        &[(20, 4, 240.0), (40, 6, 240.0)]
    };
    let iters = if smoke { 1 } else { 3 };

    let mut points_json = Vec::new();
    for &(n, m, duration_s) in points {
        let sc = Scenario::build(ScenarioConfig {
            n_clients: n,
            n_edges: m,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        })
        .expect("bench scenario builds");
        let cfg = BudgetCellConfig {
            duration_s,
            lambda_scale: 0.5,
            fault_rate: 2,
            surge_factor: 3.0,
            ..Default::default()
        };

        let oracle_r = bench(&format!("budget/oracle n={n} m={m}"), iters, || {
            std::hint::black_box(
                run_cell(&sc, &cfg, BudgetPolicy::unlimited(), Kernel::new())
                    .expect("oracle cell"),
            )
        });
        let capped_r = bench(&format!("budget/capped n={n} m={m}"), iters, || {
            std::hint::black_box(
                run_cell(&sc, &cfg, BudgetPolicy::capped(CAP_BYTES), Kernel::new())
                    .expect("capped cell"),
            )
        });

        // One measured pair outside the timed loops: the economics the
        // budget experiment reports per cell.
        let (oracle, kernel) =
            run_cell(&sc, &cfg, BudgetPolicy::unlimited(), Kernel::new()).expect("oracle cell");
        let (governed, _) =
            run_cell(&sc, &cfg, BudgetPolicy::capped(CAP_BYTES), kernel).expect("capped cell");
        assert!(governed.ctl_spend_bytes <= CAP_BYTES, "cap violated in bench cell");
        let regret_ms = governed.serving.percentiles.p99() - oracle.serving.percentiles.p99();
        println!(
            "  -> n={n}: spend {:.4} GB vs oracle {:.4} GB, {} deferrals, regret {regret_ms:+.2} ms",
            governed.ctl_spend_bytes as f64 / 1e9,
            oracle.ctl_spend_bytes as f64 / 1e9,
            governed.budget_deferrals
        );

        points_json.push(Json::obj(vec![
            ("clients", Json::Num(n as f64)),
            ("edges", Json::Num(m as f64)),
            ("duration_s", Json::Num(duration_s)),
            ("oracle_cells_per_s", Json::Num(1.0 / oracle_r.mean_s)),
            ("capped_cells_per_s", Json::Num(1.0 / capped_r.mean_s)),
            ("cap_gb", Json::Num(CAP_BYTES as f64 / 1e9)),
            ("spend_gb", Json::Num(governed.ctl_spend_bytes as f64 / 1e9)),
            ("oracle_spend_gb", Json::Num(oracle.ctl_spend_bytes as f64 / 1e9)),
            ("deferrals", Json::Num(governed.budget_deferrals as f64)),
            ("regret_ms", Json::Num(regret_ms)),
        ]));
    }

    let artifact = Json::obj(vec![
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("smoke", Json::Bool(smoke)),
        ("points", Json::Arr(points_json)),
        (
            "note",
            Json::Str("governed co-sim throughput + spend/deferral/regret; see BENCHMARKS.md".into()),
        ),
    ]);
    std::fs::write("BENCH_budget.json", artifact.to_pretty()).expect("write BENCH_budget.json");
    println!("  -> wrote BENCH_budget.json");
}
