//! Bench: warm-start re-orchestration (ISSUE 9) — cold vs warm vs
//! cache-hit re-solves/sec under a fault-churn + surge workload, plus
//! the warm-vs-cold cost gap per scale point. Writes the
//! schema-versioned `BENCH_resolve.json` artifact that CI uploads on
//! every run (BENCHMARKS.md tracks the trajectory).

mod bench_common;
use bench_common::{bench, header, smoke};

use hflop::hflop::{Instance, InstanceBuilder};
use hflop::metrics::export::SCHEMA_VERSION;
use hflop::solver::{resolve, solve, DirtySet, SolveCache, SolveOptions};
use hflop::util::json::Json;

/// One re-orchestration trigger: the churned instance plus the dirty
/// rows/columns the churn touched.
struct ChurnStep {
    inst: Instance,
    dirty: DirtySet,
}

/// Deterministic fault-churn + surge schedule: rotate a dead edge,
/// squeeze its neighbor, and every fourth step surge a fifth of the
/// devices. Each step churns the *base* instance — the installed-plan
/// repair pattern the control plane runs per trigger.
fn churn_steps(base: &Instance, steps: usize) -> Vec<ChurnStep> {
    let (n, m) = (base.n(), base.m());
    let mut out = Vec::new();
    for k in 0..steps {
        let mut inst = base.clone();
        let dead = k % m;
        let squeezed = (dead + 1) % m;
        inst.r[dead] = 0.0;
        inst.r[squeezed] *= 0.7;
        let mut rows = Vec::new();
        if k % 4 == 3 {
            for i in 0..n {
                if i % 5 == k % 5 {
                    inst.lambda[i] *= 1.8;
                    rows.push(i);
                }
            }
        }
        inst.meta = Default::default();
        let mut cols = vec![dead, squeezed];
        cols.sort_unstable();
        out.push(ChurnStep { inst, dirty: DirtySet { rows, cols } });
    }
    out
}

fn main() {
    let smoke = smoke();

    header("Warm-start re-orchestration: cold vs warm vs cache-hit re-solves");
    let points: &[(usize, usize, usize)] = if smoke {
        &[(120, 6, 4)]
    } else {
        // (n, m, churn steps); n=2000 is the acceptance-criteria point.
        &[(500, 12, 16), (2000, 24, 16)]
    };
    let iters = if smoke { 1 } else { 3 };

    let mut points_json = Vec::new();
    for &(n, m, raw_steps) in points {
        let opts = SolveOptions::heuristic();
        let base = InstanceBuilder::random(n, m, 7).t_min(n * 3 / 4).build();
        let prev = solve(&base, &opts).expect("base instance solves");
        // Keep only steps whose cold solve is feasible so every measured
        // path does identical work per step.
        let mut steps = churn_steps(&base, raw_steps);
        steps.retain(|s| solve(&s.inst, &opts).is_ok());
        assert!(!steps.is_empty(), "every churn step went infeasible at n={n}");
        if steps.len() < raw_steps {
            println!("  (n={n}: kept {}/{raw_steps} feasible churn steps)", steps.len());
        }

        let cold_r = bench(&format!("resolve/cold n={n} m={m}"), iters, || {
            for s in &steps {
                std::hint::black_box(solve(&s.inst, &opts).expect("cold solve"));
            }
        });
        let warm_r = bench(&format!("resolve/warm n={n} m={m}"), iters, || {
            for s in &steps {
                std::hint::black_box(
                    resolve(&s.inst, &prev, &s.dirty, &opts).expect("warm repair"),
                );
            }
        });
        // Cache hits: pre-warm one entry, then measure pure lookups
        // (including the content hash — the honest per-trigger cost).
        let mut cache = SolveCache::new(8);
        cache.solve(&base, &opts).expect("prime the cache");
        let hit_r = bench(&format!("resolve/cache-hit n={n} m={m}"), iters, || {
            for _ in 0..steps.len() {
                std::hint::black_box(cache.solve(&base, &opts).expect("cache hit"));
            }
        });
        assert!(cache.hits() > 0, "cache never hit");

        // Cost gap, outside the timed loops.
        let mut gaps = Vec::new();
        for s in &steps {
            let cold = solve(&s.inst, &opts).expect("cold solve");
            let warm = resolve(&s.inst, &prev, &s.dirty, &opts).expect("warm repair");
            gaps.push(warm.cost / cold.cost);
        }
        let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let max_gap = gaps.iter().fold(0.0f64, |a, &b| a.max(b));

        let per_s = |r: &bench_common::BenchResult| steps.len() as f64 / r.mean_s;
        let warm_speedup = per_s(&warm_r) / per_s(&cold_r);
        println!(
            "  -> n={n}: warm {:.1}x cold, cost gap mean {mean_gap:.4} max {max_gap:.4}",
            warm_speedup
        );

        points_json.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("m", Json::Num(m as f64)),
            ("steps", Json::Num(steps.len() as f64)),
            ("cold_per_s", Json::Num(per_s(&cold_r))),
            ("warm_per_s", Json::Num(per_s(&warm_r))),
            ("cache_hit_per_s", Json::Num(per_s(&hit_r))),
            ("warm_speedup", Json::Num(warm_speedup)),
            ("mean_cost_gap", Json::Num(mean_gap)),
            ("max_cost_gap", Json::Num(max_gap)),
        ]));
    }

    let artifact = Json::obj(vec![
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("smoke", Json::Bool(smoke)),
        ("points", Json::Arr(points_json)),
        (
            "note",
            Json::Str("cold vs warm vs cache-hit re-solve throughput; see BENCHMARKS.md".into()),
        ),
    ]);
    std::fs::write("BENCH_resolve.json", artifact.to_pretty()).expect("write BENCH_resolve.json");
    println!("  -> wrote BENCH_resolve.json");
}
