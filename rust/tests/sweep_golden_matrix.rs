//! Golden-matrix regression: the registry-driven sweep engine must
//! produce **byte-identical cells** to the pre-registry engine for the
//! existing `interference`/`fig7`/`fig8` (and `smoke`) grids.
//!
//! The oracle below is a verbatim port of the pre-registry cell runner
//! (`sweep::run_cell_at` + the old grid definitions, PR 3): one shared
//! `Scenario` per grid, direct `simulate`/`interference::run` calls, and
//! `mix_seed(root, [row, seed_base+s, mode, env])` cell seeds. It only
//! uses primitives this PR did not touch, so it genuinely pins the old
//! behavior. Both sides serialize through today's `SweepMatrix`, whose
//! v2 header adds exactly two fields (`schema_version`, the experiment
//! name — DESIGN.md §8); the *cells* array is the unchanged determinism
//! contract.
//!
//! Checks: full-pipeline byte identity on reduced-horizon variants of
//! all three grids at **1 and 8 workers**, plus cheap full-grid identity
//! of every cell seed, label and axis name against the legacy formulas.

use hflop::experiments::interference::{self, solve_options_for, InterferenceConfig, Preset};
use hflop::experiments::scenario::{Scenario, ScenarioConfig};
use hflop::experiments::sweep::{run_grid, CellOutcome, SweepGrid, SweepMatrix};
use hflop::inference::simulation::{simulate, ServingConfig};
use hflop::inference::LatencyModel;
use hflop::metrics::cost::{flat_fl_bytes, hfl_bytes};
use hflop::solver::LsMode;
use hflop::util::json::Json;
use hflop::util::rng::mix_seed;

// ----- the pre-registry engine, kept verbatim as the oracle -----------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum StaticSetup {
    Flat,
    Location,
    Hflop,
}

#[derive(Clone, Copy)]
enum Workload {
    Static(StaticSetup),
    Cosim(Preset),
}

struct LegacyRow {
    name: &'static str,
    workload: Workload,
}

struct LegacyEnv {
    name: String,
    interference_factor: f64,
    speedup: f64,
    lambda_scale: f64,
}

struct LegacyGrid {
    name: &'static str,
    experiment: &'static str, // v2 header field only; not part of the cells
    scenario: ScenarioConfig,
    rows: Vec<LegacyRow>,
    seed_base: u64,
    n_seeds: usize,
    modes: Vec<LsMode>,
    envs: Vec<LegacyEnv>,
    duration_s: f64,
    model_bytes: usize,
    root_seed: u64,
}

fn mode_name(mode: LsMode) -> &'static str {
    match mode {
        LsMode::Auto => "auto",
        LsMode::Completion => "completion",
        LsMode::Incremental => "incremental",
    }
}

impl LegacyGrid {
    fn interference(root_seed: u64) -> LegacyGrid {
        LegacyGrid {
            name: "interference",
            experiment: "interference",
            scenario: ScenarioConfig {
                n_clients: 20,
                n_edges: 4,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            rows: Preset::ALL
                .iter()
                .map(|&p| LegacyRow { name: p.name(), workload: Workload::Cosim(p) })
                .collect(),
            seed_base: 0,
            n_seeds: 2,
            modes: vec![LsMode::Completion, LsMode::Incremental],
            envs: vec![
                LegacyEnv {
                    name: "if0.25".into(),
                    interference_factor: 0.25,
                    speedup: 0.0,
                    lambda_scale: 1.0,
                },
                LegacyEnv {
                    name: "if1.0".into(),
                    interference_factor: 1.0,
                    speedup: 0.0,
                    lambda_scale: 1.0,
                },
            ],
            duration_s: 240.0,
            model_bytes: 4 * 65_536,
            root_seed,
        }
    }

    fn fig7(root_seed: u64) -> LegacyGrid {
        LegacyGrid {
            name: "fig7",
            experiment: "fig7",
            scenario: ScenarioConfig {
                n_clients: 20,
                n_edges: 4,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            rows: vec![
                LegacyRow { name: "flat", workload: Workload::Static(StaticSetup::Flat) },
                LegacyRow { name: "location", workload: Workload::Static(StaticSetup::Location) },
                LegacyRow { name: "hflop", workload: Workload::Static(StaticSetup::Hflop) },
            ],
            seed_base: 0,
            n_seeds: 6,
            modes: vec![LsMode::Auto],
            envs: vec![LegacyEnv {
                name: "base".into(),
                interference_factor: 1.0,
                speedup: 0.0,
                lambda_scale: 1.0,
            }],
            duration_s: 120.0,
            model_bytes: 4 * 65_536,
            root_seed,
        }
    }

    fn fig8(root_seed: u64) -> LegacyGrid {
        LegacyGrid {
            name: "fig8",
            n_seeds: 2,
            envs: (0..=5)
                .map(|i| {
                    let sp = i as f64 * 0.19;
                    LegacyEnv {
                        name: format!("sp{sp:.2}"),
                        interference_factor: 1.0,
                        speedup: sp,
                        lambda_scale: 10.0,
                    }
                })
                .collect(),
            duration_s: 60.0,
            ..Self::fig7(root_seed)
        }
    }

    fn n_cells(&self) -> usize {
        self.rows.len() * self.n_seeds * self.modes.len() * self.envs.len()
    }

    fn coords(&self, idx: usize) -> (usize, usize, usize, usize) {
        let e = idx % self.envs.len();
        let rest = idx / self.envs.len();
        let m = rest % self.modes.len();
        let rest = rest / self.modes.len();
        let s = rest % self.n_seeds;
        let r = rest / self.n_seeds;
        (r, s, m, e)
    }

    fn cell_seed(&self, r: usize, s: usize, m: usize, e: usize) -> u64 {
        mix_seed(self.root_seed, &[r as u64, self.seed_base + s as u64, m as u64, e as u64])
    }
}

/// Verbatim port of the pre-registry `run_cell_at`.
fn legacy_cell(sc: &Scenario, grid: &LegacyGrid, idx: usize) -> CellOutcome {
    let (r, s, m, e) = grid.coords(idx);
    let row = &grid.rows[r];
    let env = &grid.envs[e];
    let mode = grid.modes[m];
    let seed = grid.cell_seed(r, s, m, e);
    let label =
        format!("{}/s{}/{}/{}", row.name, grid.seed_base + s as u64, mode_name(mode), env.name);

    let mut rounds_completed = 0usize;
    let mut plan_swaps = 0usize;
    let mut reclusters = 0usize;
    let mut retrain_triggers = 0usize;
    let mut events_processed = 0u64;
    let mut events_cancelled = 0u64;
    let mut ctl_spend_bytes = 0u64;
    let mut budget_deferrals = 0usize;
    let serving = match row.workload {
        Workload::Static(setup) => {
            let assign = match setup {
                StaticSetup::Flat => vec![None; sc.topo.n_devices()],
                StaticSetup::Location => sc.assign_location.assign.clone(),
                StaticSetup::Hflop => sc.assign_hflop.assign.clone(),
            };
            let cfg = ServingConfig {
                assign,
                lambda: sc.lambdas().iter().map(|l| l * env.lambda_scale).collect(),
                capacity: sc.capacities(),
                latency: LatencyModel::default().with_speedup(env.speedup.min(0.95)),
                duration_s: grid.duration_s,
                queue_window_s: 0.05,
                seed,
            };
            simulate(&cfg)
        }
        Workload::Cosim(preset) => {
            let cfg = InterferenceConfig {
                preset,
                duration_s: grid.duration_s,
                interference_factor: env.interference_factor,
                lambda_scale: env.lambda_scale,
                model_bytes: grid.model_bytes,
                solve: solve_options_for(mode),
                seed,
                ..Default::default()
            };
            let out = interference::run(sc, &cfg).expect("legacy cosim cell");
            rounds_completed = out.rounds_completed;
            plan_swaps = out.plan_swaps;
            reclusters = out.reclusters;
            retrain_triggers = out.retrain_triggers;
            events_processed = out.events_processed;
            events_cancelled = out.events_cancelled;
            // The unlimited governor meters reconfiguration spend even
            // when it never denies: the oracle reads the same counters
            // the registry path surfaces through `cosim_summary`.
            ctl_spend_bytes = out.ctl_spend_bytes;
            budget_deferrals = out.budget_deferrals;
            out.serving
        }
    };

    let (eq1_cost, comm_rounds) = match row.workload {
        Workload::Static(StaticSetup::Flat) => (0.0, 100),
        Workload::Static(StaticSetup::Location) => (sc.assign_location.cost(&sc.inst), 100),
        Workload::Static(StaticSetup::Hflop) => (sc.hflop_cost, 100),
        Workload::Cosim(_) => (sc.hflop_cost, rounds_completed),
    };
    let comm_bytes = match row.workload {
        Workload::Static(StaticSetup::Flat) => {
            flat_fl_bytes(sc.topo.n_devices(), comm_rounds, grid.model_bytes)
        }
        Workload::Static(StaticSetup::Location) => {
            hfl_bytes(&sc.inst, &sc.assign_location, comm_rounds, grid.model_bytes)
        }
        _ => hfl_bytes(&sc.inst, &sc.assign_hflop, comm_rounds, grid.model_bytes),
    };

    CellOutcome {
        row: r,
        seed_idx: s,
        mode_idx: m,
        env_idx: e,
        label,
        cell_seed: seed,
        requests: serving.total(),
        served_at_edge: serving.served_at_edge,
        spilled_to_cloud: serving.spilled_to_cloud,
        direct_to_cloud: serving.direct_to_cloud,
        spill_fraction: serving.spill_fraction(),
        mean_ms: serving.latency.mean(),
        std_ms: serving.latency.std(),
        min_ms: serving.latency.min(),
        max_ms: serving.latency.max(),
        p50_ms: serving.percentiles.p50(),
        p90_ms: serving.percentiles.p90(),
        p99_ms: serving.percentiles.p99(),
        rounds_completed,
        plan_swaps,
        reclusters,
        retrain_triggers,
        events_processed,
        events_cancelled,
        eq1_cost,
        comm_gb: comm_bytes as f64 / 1e9,
        ctl_spend_gb: ctl_spend_bytes as f64 / 1e9,
        budget_deferrals,
        regret_ms: 0.0,
        wall_s: 0.0,
    }
}

/// Run the legacy grid serially (one shared scenario, grid order) and
/// wrap it in today's `SweepMatrix` so both sides share one serializer.
fn legacy_matrix(grid: &LegacyGrid) -> SweepMatrix {
    let sc = Scenario::build(grid.scenario.clone()).expect("legacy scenario");
    let cells: Vec<CellOutcome> = (0..grid.n_cells()).map(|i| legacy_cell(&sc, grid, i)).collect();
    SweepMatrix {
        grid_name: grid.name.to_string(),
        root_seed: grid.root_seed,
        experiment: grid.experiment.to_string(),
        row_names: grid.rows.iter().map(|r| r.name.to_string()).collect(),
        seeds: (0..grid.n_seeds).map(|s| grid.seed_base + s as u64).collect(),
        mode_names: grid.modes.iter().map(|&m| mode_name(m).to_string()).collect(),
        env_names: grid.envs.iter().map(|e| e.name.clone()).collect(),
        duration_s: grid.duration_s,
        cells,
    }
}

/// Strip `wall_s` influence: serialization already excludes it, so JSON
/// comparison is the right equality.
fn golden_check(legacy: &LegacyGrid, new: &SweepGrid) {
    assert_eq!(legacy.n_cells(), new.n_cells(), "{}: cell counts differ", legacy.name);
    let oracle = legacy_matrix(legacy).to_json().to_pretty();
    for workers in [1, 8] {
        let got = run_grid(new, workers).unwrap().to_json().to_pretty();
        assert_eq!(
            oracle.as_bytes(),
            got.as_bytes(),
            "{}: registry sweep diverged from the pre-registry engine at {workers} workers",
            legacy.name
        );
    }
}

// ----- reduced-horizon variants (identical shrink on both sides) ------------

fn shrink_legacy(mut g: LegacyGrid, duration_s: f64, n_seeds: usize) -> LegacyGrid {
    g.duration_s = duration_s;
    g.n_seeds = n_seeds;
    g
}

fn shrink_new(mut g: SweepGrid, duration_s: f64, n_seeds: usize) -> SweepGrid {
    use hflop::config::params::Value;
    g.set_base("duration_s", Value::Float(duration_s));
    g.duration_s = duration_s;
    g.n_seeds = n_seeds;
    g
}

#[test]
fn golden_interference_grid_bit_identical_at_1_and_8_workers() {
    // Small world + short horizon on BOTH sides; all four presets, both
    // solver engines, both interference factors stay covered.
    let mut legacy = shrink_legacy(LegacyGrid::interference(2026), 25.0, 1);
    legacy.scenario.n_clients = 12;
    legacy.scenario.n_edges = 3;
    let mut new = shrink_new(SweepGrid::interference(2026), 25.0, 1);
    {
        use hflop::config::params::Value;
        new.set_base("clients", Value::Int(12));
        new.set_base("edges", Value::Int(3));
    }
    golden_check(&legacy, &new);
}

#[test]
fn golden_fig7_grid_bit_identical_at_1_and_8_workers() {
    let legacy = shrink_legacy(LegacyGrid::fig7(2026), 20.0, 2);
    let new = shrink_new(SweepGrid::fig7(2026), 20.0, 2);
    golden_check(&legacy, &new);
}

#[test]
fn golden_fig8_grid_bit_identical_at_1_and_8_workers() {
    let mut legacy = shrink_legacy(LegacyGrid::fig8(2026), 8.0, 1);
    legacy.envs.truncate(3);
    let mut new = shrink_new(SweepGrid::fig8(2026), 8.0, 1);
    new.envs.truncate(3);
    golden_check(&legacy, &new);
}

#[test]
fn full_grids_keep_legacy_cell_seeds_labels_and_axis_names() {
    // Cheap identity over the FULL acceptance grids (no simulation):
    // every cell seed and label must match the pre-registry formulas.
    for (legacy, new) in [
        (LegacyGrid::interference(7), SweepGrid::interference(7)),
        (LegacyGrid::fig7(7), SweepGrid::fig7(7)),
        (LegacyGrid::fig8(7), SweepGrid::fig8(7)),
    ] {
        assert_eq!(legacy.n_cells(), new.n_cells(), "{}", legacy.name);
        assert_eq!(
            legacy.rows.iter().map(|r| r.name.to_string()).collect::<Vec<_>>(),
            new.rows.iter().map(|r| r.name.clone()).collect::<Vec<_>>()
        );
        assert_eq!(
            legacy.modes.iter().map(|&m| mode_name(m).to_string()).collect::<Vec<_>>(),
            new.modes.iter().map(|m| m.name.clone()).collect::<Vec<_>>()
        );
        assert_eq!(
            legacy.envs.iter().map(|e| e.name.clone()).collect::<Vec<_>>(),
            new.envs.iter().map(|e| e.name.clone()).collect::<Vec<_>>()
        );
        for idx in 0..legacy.n_cells() {
            let (r, s, m, e) = legacy.coords(idx);
            assert_eq!((r, s, m, e), new.coords(idx), "{} idx {idx}", legacy.name);
            assert_eq!(
                legacy.cell_seed(r, s, m, e),
                new.cell_seed(r, s, m, e),
                "{}: cell seed drifted at {:?}",
                legacy.name,
                (r, s, m, e)
            );
            let legacy_label = format!(
                "{}/s{}/{}/{}",
                legacy.rows[r].name,
                legacy.seed_base + s as u64,
                mode_name(legacy.modes[m]),
                legacy.envs[e].name
            );
            assert_eq!(legacy_label, new.cell_label(r, s, m, e));
        }
    }
}

#[test]
fn v2_header_adds_only_schema_version_and_experiment() {
    // The compatibility contract of DESIGN.md §8: relative to the v1
    // matrix, v2 adds exactly `schema_version` (top level) and
    // `grid.experiment`; cells carry the identical key set.
    let m = legacy_matrix(&shrink_legacy(LegacyGrid::fig7(1), 5.0, 1)).to_json();
    let top = m.as_obj().unwrap();
    assert_eq!(
        top.keys().map(String::as_str).collect::<Vec<_>>(),
        vec!["cells", "grid", "schema_version"]
    );
    let grid = m.get("grid").unwrap().as_obj().unwrap();
    assert_eq!(
        grid.keys().map(String::as_str).collect::<Vec<_>>(),
        vec![
            "duration_s", "envs", "experiment", "modes", "n_cells", "name", "root_seed", "rows",
            "seeds"
        ]
    );
    let cell = m.get("cells").unwrap().as_arr().unwrap()[0].as_obj().unwrap();
    // The v1 cell key set plus the three budget control-plane keys
    // (additive, so the schema version stays at 2 — DESIGN.md §8).
    let keys: Vec<&str> = cell.keys().map(String::as_str).collect();
    assert_eq!(
        keys,
        vec![
            "budget_deferrals",
            "cell_seed",
            "comm_gb",
            "ctl_spend_gb",
            "direct_to_cloud",
            "eq1_cost",
            "events_cancelled",
            "events_processed",
            "label",
            "max_ms",
            "mean_ms",
            "min_ms",
            "p50_ms",
            "p90_ms",
            "p99_ms",
            "plan_swaps",
            "reclusters",
            "regret_ms",
            "requests",
            "retrain_triggers",
            "rounds_completed",
            "served_at_edge",
            "spill_fraction",
            "spilled_to_cloud",
            "std_ms"
        ]
    );
    assert!(Json::parse(&m.to_pretty()).is_ok());
}
