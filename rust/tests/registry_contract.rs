//! Table-drift guard (same spirit as `solver_nan_guard.rs`): the
//! experiment registry and DESIGN.md §5 must mirror each other exactly.
//! Every `REGISTRY` entry needs a doc row in the §5 contract table, and
//! every documented experiment must actually be registered — so the
//! docs can never silently rot as experiments are added or renamed.

use std::collections::BTreeSet;

use hflop::experiments::registry::{self, REGISTRY};

fn design_md() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../DESIGN.md");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// The §5 section body (from its header to the next `## §`).
fn section5(text: &str) -> &str {
    let start = text.find("## §5").expect("DESIGN.md lost its §5 header");
    let rest = &text[start..];
    let end = rest[5..].find("\n## §").map(|i| i + 5).unwrap_or(rest.len());
    &rest[..end]
}

/// Experiment names documented in the §5 contract table: first cell of
/// each body row, backticked (`| \`name\` | ... |`).
fn documented_names(sec: &str) -> BTreeSet<String> {
    sec.lines()
        .filter_map(|line| {
            let line = line.trim();
            let rest = line.strip_prefix("| `")?;
            let name = rest.split('`').next()?;
            Some(name.to_string())
        })
        .collect()
}

#[test]
fn every_registry_entry_has_a_design_doc_row_and_vice_versa() {
    let text = design_md();
    let documented = documented_names(section5(&text));
    let registered: BTreeSet<String> =
        registry::names().iter().map(|s| s.to_string()).collect();

    let undocumented: Vec<&String> = registered.difference(&documented).collect();
    assert!(
        undocumented.is_empty(),
        "registered experiments missing from the DESIGN.md §5 contract table: {undocumented:?}"
    );
    let stale: Vec<&String> = documented.difference(&registered).collect();
    assert!(
        stale.is_empty(),
        "DESIGN.md §5 documents experiments that are not in REGISTRY: {stale:?}"
    );
    assert_eq!(documented.len(), REGISTRY.len());
}

#[test]
fn design_section5_mentions_the_trait_contract() {
    let text = design_md();
    let sec = section5(&text);
    // The section is the registry contract: the trait surface and the
    // resolution/report machinery must be named so readers land on the
    // right types.
    for needle in ["Experiment", "param_schema", "ExperimentCtx", "Report", "--set"] {
        assert!(sec.contains(needle), "DESIGN.md §5 no longer mentions '{needle}'");
    }
}

#[test]
fn design_section8_documents_schema_version() {
    let text = design_md();
    let start = text.find("## §8").expect("DESIGN.md lost its §8 header");
    let sec = &text[start..];
    assert!(
        sec.contains("schema_version"),
        "DESIGN.md §8 must carry the schema_version compatibility note"
    );
}
