//! Integration: the real serving hot path (dynamic batcher over PJRT
//! predict artifacts) and the orchestration loop end-to-end.

use hflop::inference::serving::{BatchingServer, InferenceRequest};
use hflop::orchestrator::{Gpo, InferenceController, InferenceCtlConfig, LearningController, LearningCtlConfig};
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::topology::GeoPoint;
use hflop::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
}

#[test]
fn batcher_results_match_direct_predict() {
    let Some(manifest) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let engine = Engine::new(&manifest, "small", Preload::Serving).unwrap();
    let params = manifest.load_init_params(engine.variant()).unwrap();
    let seq = engine.variant().seq_len;
    let mut server = BatchingServer::new(&engine, params.clone());
    let mut rng = Rng::new(3);

    let windows: Vec<Vec<f32>> = (0..13)
        .map(|_| (0..seq).map(|_| rng.normal() as f32).collect())
        .collect();
    // Deterministic caller clock: one tick per submission.
    let mut results = Vec::new();
    for (i, w) in windows.iter().enumerate() {
        let now_s = i as f64 * 1e-3;
        results
            .extend(server.submit(InferenceRequest { id: i as u64, window: w.clone() }, now_s).unwrap());
    }
    results.extend(server.flush(13.0 * 1e-3).unwrap());
    assert_eq!(results.len(), 13);

    for (id, pred) in results {
        let direct = engine.predict(&params, &windows[id as usize]).unwrap();
        assert!(
            (pred - direct[0]).abs() < 1e-5,
            "req {id}: batched {pred} vs direct {}",
            direct[0]
        );
    }
    assert!(server.stats.batches >= 2);
    assert_eq!(server.stats.requests, 13);
}

#[test]
fn batcher_param_update_changes_predictions() {
    let Some(manifest) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let engine = Engine::new(&manifest, "small", Preload::Serving).unwrap();
    let params = manifest.load_init_params(engine.variant()).unwrap();
    let seq = engine.variant().seq_len;
    let mut server = BatchingServer::new(&engine, params.clone());
    let window: Vec<f32> = (0..seq).map(|i| i as f32 * 0.1).collect();

    server.submit(InferenceRequest { id: 0, window: window.clone() }, 0.0).unwrap();
    let before = server.flush(0.001).unwrap()[0].1;

    // New model version (e.g. after a global round): all-zero params.
    server.update_params(vec![0.0; params.len()]);
    server.submit(InferenceRequest { id: 1, window }, 0.002).unwrap();
    let after = server.flush(0.003).unwrap()[0].1;
    assert_ne!(before, after);
    assert!(after.abs() < 1e-6, "zero model must predict 0, got {after}");
}

#[test]
fn queue_latency_bit_identical_on_virtual_clock() {
    // The satellite fix for the old wall-clock batcher: with submit/flush
    // driven by a caller-supplied clock, request_ms is a pure function of
    // the inputs and must not drift between runs.
    let Some(manifest) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let run = || {
        let engine = Engine::new(&manifest, "small", Preload::Serving).unwrap();
        let params = manifest.load_init_params(engine.variant()).unwrap();
        let seq = engine.variant().seq_len;
        let mut server = BatchingServer::new(&engine, params);
        let mut rng = Rng::new(7);
        for id in 0..13u64 {
            let w: Vec<f32> = (0..seq).map(|_| rng.normal() as f32).collect();
            server.submit(InferenceRequest { id, window: w }, id as f64 * 0.25).unwrap();
        }
        server.flush(4.0).unwrap();
        (server.stats.request_ms.mean(), server.stats.requests)
    };
    let (mean_a, n_a) = run();
    let (mean_b, n_b) = run();
    assert_eq!(n_a, n_b);
    assert_eq!(
        mean_a.to_bits(),
        mean_b.to_bits(),
        "virtual-clock queue latency must be bit-identical across runs"
    );
}

#[test]
fn orchestration_loop_end_to_end() {
    // GPO inventory -> learning controller clusters (HFLOP) -> inference
    // controller monitors accuracy -> degradation triggers a re-task ->
    // edge failure triggers re-clustering. No artifacts needed.
    let mut gpo = Gpo::new();
    for i in 0..12 {
        gpo.register_device(
            i,
            GeoPoint { lat: 34.02 + 0.01 * (i % 4) as f64, lon: -118.42 + 0.02 * (i / 4) as f64 },
        );
    }
    for j in 0..3 {
        gpo.register_edge(
            100 + j,
            GeoPoint { lat: 34.03 + 0.03 * j as f64, lon: -118.40 + 0.03 * j as f64 },
            10.0,
        );
    }
    let mut lc = LearningController::new(LearningCtlConfig::default());
    for i in 0..12 {
        lc.set_lambda(i, 1.5);
    }
    let plan = lc.cluster(&mut gpo).unwrap().clone();
    assert_eq!(plan.device_ids.len(), 12);
    assert!(plan.assignment.n_open() >= 1);

    // Inference controller: healthy -> degraded -> trigger.
    let mut ic = InferenceController::new(InferenceCtlConfig {
        mse_threshold: 0.2,
        alpha: 0.5,
        min_observations: 3,
        cooldown: 10,
    });
    for _ in 0..5 {
        assert!(!ic.observe_mse(0.05));
    }
    let mut triggered = false;
    for _ in 0..6 {
        triggered |= ic.observe_mse(0.9);
    }
    assert!(triggered, "accuracy degradation must trigger a new HFL task");

    // Environmental event: kill an edge used by the plan -> re-cluster.
    let used_edge = plan
        .edge_ids
        .iter()
        .enumerate()
        .find(|(c, _)| plan.assignment.open[*c])
        .map(|(_, &e)| e)
        .unwrap();
    gpo.fail_edge(used_edge);
    assert!(lc.on_environment_change(&mut gpo).unwrap());
    let new_plan = lc.current_plan.as_ref().unwrap();
    assert!(!new_plan.edge_ids.contains(&used_edge));
    for dev in 0..12 {
        // Everyone still served by a live aggregator.
        assert!(new_plan.aggregator_of(dev).is_some());
    }
}
