//! Refactor property tests: the flat `core::DenseMatrix` storage and the
//! incremental evaluator must be behaviourally indistinguishable from the
//! seed's nested-vec / full-rescore implementations.
//!
//! (a) exact-mode solve costs are bit-identical to a nested-`Vec<Vec<f64>>`
//!     reference evaluation of Eq. 1 (same summation order as the seed);
//! (b) the incremental evaluator's running cost matches a full
//!     `Assignment::cost` recompute after every accepted move;
//! (c) heuristic costs dominate the LP-relaxation lower bound.

use hflop::hflop::{Instance, InstanceBuilder};
use hflop::solver::local_search::{local_search, LocalSearchOptions, LsMode};
use hflop::solver::lp::LpResult;
use hflop::solver::milp::build_relaxation;
use hflop::solver::{complete_assignment, solve, Assignment, IncrementalEvaluator, SolveOptions};

/// Reference Eq. 1 evaluation over nested rows — the seed's storage
/// layout and summation order, used to pin bit-identical behaviour of the
/// flat row-major storage.
fn nested_cost(inst: &Instance, nested: &[Vec<f64>], sol: &Assignment) -> f64 {
    let local: f64 = sol
        .assign
        .iter()
        .enumerate()
        .filter_map(|(i, &a)| a.map(|j| nested[i][j]))
        .sum();
    let global: f64 = sol
        .open
        .iter()
        .enumerate()
        .filter_map(|(j, &o)| o.then_some(inst.c_e[j]))
        .sum();
    local * inst.l + global
}

#[test]
fn exact_solve_bit_identical_to_nested_vec_reference() {
    let mut solved = 0usize;
    for seed in 0..24u64 {
        let n = 8 + (seed % 5) as usize;
        let m = 3 + (seed % 2) as usize;
        let inst = InstanceBuilder::random(n, m, seed).t_min(n - 2).build();
        let nested: Vec<Vec<f64>> = inst.c_d.row_iter().map(|r| r.to_vec()).collect();
        let Ok(sol) = solve(&inst, &SolveOptions::exact()) else {
            continue; // infeasible draws are legitimate; skip
        };
        assert!(sol.proven_optimal, "seed {seed}");
        sol.assignment.check_feasible(&inst).unwrap();
        let reference = nested_cost(&inst, &nested, &sol.assignment);
        let flat = sol.assignment.cost(&inst);
        assert_eq!(
            reference.to_bits(),
            flat.to_bits(),
            "seed {seed}: nested {reference} != flat {flat}"
        );
        assert!((sol.cost - flat).abs() < 1e-9, "seed {seed}");
        solved += 1;
    }
    assert!(solved >= 20, "only {solved} instances solved — widen the sweep");
}

#[test]
fn incremental_evaluator_matches_full_recompute_after_every_move() {
    let mut checked = 0usize;
    for seed in 0..20u64 {
        let inst = InstanceBuilder::random(16, 5, 400 + seed).t_min(12).build();
        let Some(start) = complete_assignment(&inst, &[true; 5]) else { continue };
        let mut ev = IncrementalEvaluator::new(&inst, &start);
        // First-improvement sweeps; cross-check after each accepted move.
        for _sweep in 0..4 {
            for i in 0..inst.n() {
                let Some(cur) = ev.assign_of(i) else { continue };
                for j in 0..inst.m() {
                    if j == cur {
                        continue;
                    }
                    if let Some(delta) = ev.reassign_delta(i, j) {
                        if delta < -1e-12 {
                            ev.apply_reassign(i, j);
                            let full = ev.assignment().cost(&inst);
                            assert!(
                                (ev.cost() - full).abs() <= 1e-9 * full.abs().max(1.0),
                                "seed {seed}: running {} vs full {full}",
                                ev.cost()
                            );
                            checked += 1;
                            break;
                        }
                    }
                }
            }
        }
        let end = ev.assignment();
        assert!(end.cost(&inst) <= start.cost(&inst) + 1e-9, "seed {seed}");
    }
    assert!(checked > 0, "sweep exercised no moves — instances too easy");
}

#[test]
fn incremental_local_search_cost_is_exact_full_recompute() {
    for seed in 0..20u64 {
        let inst = InstanceBuilder::unit_cost(40, 6, 200 + seed).build();
        let opts = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
        let ls = local_search(&inst, &opts);
        let sol = ls.best.expect("unit-cost instances are feasible");
        sol.check_feasible(&inst).unwrap();
        assert_eq!(
            ls.cost.to_bits(),
            sol.cost(&inst).to_bits(),
            "seed {seed}: reported cost must be the drift-free recompute"
        );
    }
}

#[test]
fn heuristic_cost_dominates_lp_lower_bound() {
    for seed in 0..20u64 {
        let inst = InstanceBuilder::unit_cost(24, 4, 700 + seed).build();
        let bound = match build_relaxation(&inst, &[], false).solve() {
            LpResult::Optimal { obj, .. } => obj,
            other => panic!("seed {seed}: LP should solve: {other:?}"),
        };
        let he = solve(&inst, &SolveOptions::heuristic()).unwrap();
        assert!(
            he.cost >= bound - 1e-6,
            "seed {seed}: heuristic {} below LP bound {bound}",
            he.cost
        );
        for mode in [LsMode::Completion, LsMode::Incremental] {
            let ls = local_search(&inst, &LocalSearchOptions { mode, ..Default::default() });
            let cost = ls.cost;
            assert!(
                cost >= bound - 1e-6,
                "seed {seed} mode {mode:?}: {cost} below LP bound {bound}"
            );
        }
    }
}

#[test]
fn core_types_expose_flat_views() {
    let inst = InstanceBuilder::unit_cost(10, 3, 1).build();
    assert_eq!(inst.c_d.rows(), 10);
    assert_eq!(inst.c_d.cols(), 3);
    assert_eq!(inst.c_d.as_slice().len(), 30);
    for row in &inst.c_d {
        assert_eq!(row.len(), 3);
    }
    assert_eq!(inst.lambda.len(), 10);
    assert!(inst.lambda.total() > 0.0);
    assert!((inst.r.total() - 2.0 * inst.lambda.total()).abs() < 1e-9);
}
