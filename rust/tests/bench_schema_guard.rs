//! Guard: the committed bench artifacts stay parseable and
//! schema-versioned.
//!
//! `benches/bench_kernel.rs` overwrites `BENCH_kernel.json` and
//! `benches/bench_solver.rs` overwrites `BENCH_solver.json` on every run
//! (CI uploads both as artifacts), so each file's shape is a contract:
//! downstream tooling keys on `schema_version` to interpret the
//! trajectory. This test pins that the checked-in baselines (or freshly
//! regenerated artifacts — the benches write to the same paths) parse as
//! JSON and carry the current schema version.

use hflop::metrics::export::SCHEMA_VERSION;
use hflop::util::json::Json;

const ARTIFACTS: &[&str] = &[
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_kernel.json"),
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_solver.json"),
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_resolve.json"),
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_budget.json"),
];

#[test]
fn bench_artifacts_are_schema_versioned_json() {
    for path in ARTIFACTS {
        let raw = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("bench artifact must be committed at {path}: {e}"));
        let json = Json::parse(&raw).unwrap_or_else(|e| panic!("{path} parses as JSON: {e}"));
        let version = json
            .get("schema_version")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("{path} carries a numeric schema_version"));
        assert_eq!(version as u32, SCHEMA_VERSION, "{path}: artifact schema version drifted");
    }
}
