//! Guard: the committed kernel-bench artifact stays parseable and
//! schema-versioned.
//!
//! `benches/bench_kernel.rs` overwrites `BENCH_kernel.json` on every run
//! (CI uploads it as an artifact), so the file's shape is a contract:
//! downstream tooling keys on `schema_version` to interpret the
//! trajectory. This test pins that the checked-in baseline (or a
//! freshly regenerated artifact — the bench writes to the same path)
//! parses as JSON and carries the current schema version.

use hflop::metrics::export::SCHEMA_VERSION;
use hflop::util::json::Json;

const ARTIFACT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_kernel.json");

#[test]
fn bench_kernel_artifact_is_schema_versioned_json() {
    let raw = std::fs::read_to_string(ARTIFACT)
        .unwrap_or_else(|e| panic!("BENCH_kernel.json must be committed at {ARTIFACT}: {e}"));
    let json = Json::parse(&raw).expect("BENCH_kernel.json parses as JSON");
    let version = json
        .get("schema_version")
        .and_then(Json::as_f64)
        .expect("BENCH_kernel.json carries a numeric schema_version");
    assert_eq!(version as u32, SCHEMA_VERSION, "artifact schema version drifted");
}
