//! Acceptance properties of the sharded region-parallel solver
//! (solver::sharded):
//!
//! * worker-count independence — the same root seed yields a
//!   byte-identical assignment, open set and cost at 1, 2 and 8 workers;
//! * feasibility — across ≥20 seeds, the merged + rescued + repaired
//!   solution passes the dense `check_feasible` (so the repair pass never
//!   breaks capacity, linking or participation), and the sparse-side cost
//!   matches the dense evaluation;
//! * soundness of the gap reference — every solve lands at or above the
//!   aggregated-LP lower bound;
//! * the auto tier routes small sparse instances dense and large ones
//!   sharded.

use hflop::hflop::SparseInstance;
use hflop::solver::{aggregated_lp_bound, solve_sparse, SolveOptions};

fn opts_with(seed: u64, workers: usize) -> SolveOptions {
    let mut o = SolveOptions::sharded();
    o.shard.root_seed = seed;
    o.shard.workers = workers;
    o
}

#[test]
fn worker_count_never_changes_the_solution() {
    for seed in 0..20u64 {
        let sp = SparseInstance::clustered(200, 8, 100 + seed, 4);
        let base = solve_sparse(&sp, &opts_with(seed, 1)).unwrap().solution;
        for workers in [2, 8] {
            let out = solve_sparse(&sp, &opts_with(seed, workers)).unwrap().solution;
            assert_eq!(out.assignment.assign, base.assignment.assign, "seed {seed} w{workers}");
            assert_eq!(out.assignment.open, base.assignment.open, "seed {seed} w{workers}");
            assert_eq!(out.cost.to_bits(), base.cost.to_bits(), "seed {seed} w{workers}");
        }
    }
}

#[test]
fn sharded_solutions_stay_feasible_and_above_bound_across_seeds() {
    for seed in 0..20u64 {
        let sp = SparseInstance::clustered(240, 8, 500 + seed, 4);
        let out = solve_sparse(&sp, &opts_with(seed, 4)).unwrap();
        let sol = out.solution;
        let stats = out.sharded.expect("sharded stats");
        assert!(stats.regions >= 1);
        // The dense equivalent re-checks every constraint the repair and
        // rescue passes touched: capacity residuals, assigned-edge-open
        // linking, and t_min participation.
        let dense = sp.to_dense();
        sol.assignment.check_feasible(&dense).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(
            (sol.cost - sol.assignment.cost(&dense)).abs() < 1e-9,
            "seed {seed}: sparse cost drifted from dense evaluation"
        );
        let bound = aggregated_lp_bound(&sp);
        assert!(sol.cost >= bound - 1e-9, "seed {seed}: cost {} < bound {bound}", sol.cost);
    }
}

#[test]
fn auto_tier_routes_by_instance_size() {
    let sp = SparseInstance::clustered(300, 8, 3, 4);
    // 2400 x-variables: far below the default cutoff, dense fast path.
    let small = solve_sparse(&sp, &SolveOptions::auto()).unwrap();
    assert!(small.sharded.is_none());
    // Lowering the cutoff routes the very same instance sharded.
    let mut opts = SolveOptions::auto();
    opts.auto_sharded_above = 1_000;
    let big = solve_sparse(&sp, &opts).unwrap();
    assert!(big.sharded.is_some());
    big.solution.assignment.check_feasible(&sp.to_dense()).unwrap();
}
