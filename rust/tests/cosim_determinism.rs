//! Determinism guarantees of the co-simulation kernel, end to end:
//! same seed ⇒ bit-identical event trace and outcome, including a
//! mid-run orchestrator plan swap; kernel ordering is FIFO at equal
//! timestamps.

use hflop::experiments::interference::{run, InterferenceConfig, Preset};
use hflop::experiments::{Scenario, ScenarioConfig};
use hflop::sim::Kernel;
use hflop::util::rng::Rng;

fn scenario() -> Scenario {
    Scenario::build(ScenarioConfig {
        n_clients: 12,
        n_edges: 3,
        weeks: 5,
        balanced_clients: false,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn cosim_trace_bit_identical_across_runs_with_plan_swap() {
    let sc = scenario();
    // Edge failure with no training interference: deterministic mid-run
    // re-solve and plan swap (the swap itself is part of the contract).
    let cfg = InterferenceConfig {
        preset: Preset::EdgeFailure,
        duration_s: 120.0,
        lambda_scale: 0.5,
        interference_factor: 1.0,
        record_trace: true,
        ..Default::default()
    };
    let a = run(&sc, &cfg).unwrap();
    let b = run(&sc, &cfg).unwrap();

    assert!(a.plan_swaps >= 1, "the run must exercise a mid-run plan swap");
    assert_eq!(a.trace.len(), b.trace.len());
    assert_eq!(a.trace, b.trace, "event traces diverged");
    assert_eq!(a.serving.total(), b.serving.total());
    assert_eq!(a.serving.served_at_edge, b.serving.served_at_edge);
    assert_eq!(a.serving.spilled_to_cloud, b.serving.spilled_to_cloud);
    assert_eq!(a.serving.direct_to_cloud, b.serving.direct_to_cloud);
    assert_eq!(a.serving.latency.mean().to_bits(), b.serving.latency.mean().to_bits());
    assert_eq!(a.serving.latency.std().to_bits(), b.serving.latency.std().to_bits());
    assert_eq!(a.serving.samples, b.serving.samples);
    assert_eq!(a.plan_swaps, b.plan_swaps);
    assert_eq!(a.reclusters, b.reclusters);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.events_cancelled, b.events_cancelled);
}

#[test]
fn different_seed_changes_the_trace() {
    let sc = scenario();
    let cfg = InterferenceConfig {
        preset: Preset::Steady,
        duration_s: 60.0,
        lambda_scale: 0.5,
        record_trace: true,
        ..Default::default()
    };
    let a = run(&sc, &cfg).unwrap();
    let cfg2 = InterferenceConfig { seed: cfg.seed + 1, ..cfg };
    let c = run(&sc, &cfg2).unwrap();
    assert_ne!(a.trace, c.trace);
}

#[test]
fn kernel_is_fifo_at_equal_timestamps() {
    // Property: among live events at one timestamp, delivery order is
    // insertion order — across many random batches with interleaved
    // cancellations and tag invalidations.
    let mut rng = Rng::new(2026);
    for round in 0..20 {
        let mut k: Kernel<usize> = Kernel::new();
        let mut expect: Vec<(u64, usize)> = Vec::new();
        let mut cancels = Vec::new();
        let mut tagged_dead = 0usize;
        for i in 0..400 {
            let t = rng.below(8) as f64;
            if rng.chance(0.15) {
                // Tagged under tag 1; invalidated below -> must not fire.
                k.schedule_tagged(t, 1, i);
                tagged_dead += 1;
            } else {
                let id = k.schedule(t, i);
                if rng.chance(0.2) {
                    cancels.push(id);
                } else {
                    expect.push((t as u64, i));
                }
            }
        }
        assert_eq!(k.invalidate_tag(1), tagged_dead, "round {round}");
        for id in cancels {
            assert!(k.cancel(id));
        }
        // A stable sort by time is exactly the kernel's ordering contract.
        expect.sort_by_key(|&(t, _)| t);
        let got: Vec<(u64, usize)> =
            std::iter::from_fn(|| k.next().map(|(t, e)| (t as u64, e))).collect();
        assert_eq!(got, expect, "round {round}");
        assert!(k.is_empty());
    }
}

#[test]
fn kernel_clock_never_regresses_under_cancellation() {
    let mut rng = Rng::new(7);
    let mut k: Kernel<u32> = Kernel::new();
    let mut ids = Vec::new();
    for i in 0..200u32 {
        ids.push(k.schedule(rng.uniform(0.0, 50.0), i));
    }
    for (n, id) in ids.into_iter().enumerate() {
        if n % 3 == 0 {
            k.cancel(id);
        }
    }
    let mut last = 0.0;
    while let Some((t, _)) = k.next() {
        assert!(t >= last, "{t} < {last}");
        last = t;
    }
}
