//! Warm-start quality contract (ISSUE 9 satellite): across seeded
//! fault-churn scenarios, `solver::resolve` must (a) stay feasible
//! whenever the cold solve of the churned instance is feasible, (b) land
//! within a fixed cost factor of that cold solve, and (c) be
//! bit-identical across repeated runs on identical inputs.

use hflop::hflop::{Instance, InstanceBuilder};
use hflop::solver::{resolve, solve, DirtySet, SolveOptions};

const N: usize = 60;
const M: usize = 6;
const T_MIN: usize = 45;
const SEEDS: u64 = 30;
/// Warm repair may trail the cold solve, but never by more than this.
const COST_FACTOR: f64 = 2.0;

/// Fault-churn for one seed: kill one edge, squeeze a second, surge a
/// third of the devices. Returns the churned instance plus the dirty
/// rows/columns the mutations touched.
fn churn(base: &Instance, seed: u64) -> (Instance, DirtySet) {
    let mut inst = base.clone();
    let dead = (seed as usize) % M;
    let squeezed = (dead + 1) % M;
    inst.r[dead] = 0.0;
    inst.r[squeezed] *= 0.6;
    let mut rows = Vec::new();
    for i in 0..N {
        if i % 3 == (seed as usize) % 3 {
            inst.lambda[i] *= 1.5;
            rows.push(i);
        }
    }
    // The λ prefix table and validation flag describe the base instance;
    // reset so the mutated copy is re-validated from scratch.
    inst.meta = Default::default();
    let mut cols = vec![dead, squeezed];
    cols.sort_unstable();
    (inst, DirtySet { rows, cols })
}

#[test]
fn warm_resolve_quality_over_seeded_churn() {
    let opts = SolveOptions::heuristic();
    let mut scenarios = 0usize;
    for seed in 0..SEEDS {
        let base = InstanceBuilder::random(N, M, seed).t_min(T_MIN).build();
        let Ok(prev) = solve(&base, &opts) else { continue };
        let (churned, dirty) = churn(&base, seed);
        // The contract is conditional on the cold solve being feasible.
        let Ok(cold) = solve(&churned, &opts) else { continue };
        scenarios += 1;

        let warm = resolve(&churned, &prev, &dirty, &opts).unwrap_or_else(|e| {
            panic!("seed {seed}: warm repair infeasible where cold succeeded: {e}")
        });
        warm.assignment.check_feasible(&churned).unwrap_or_else(|e| {
            panic!("seed {seed}: warm repair violated feasibility: {e}")
        });
        assert!(
            warm.cost <= COST_FACTOR * cold.cost + 1e-9,
            "seed {seed}: warm cost {} vs cold cost {} exceeds factor {COST_FACTOR}",
            warm.cost,
            cold.cost
        );

        // Determinism: identical inputs, bit-identical outputs.
        let again = resolve(&churned, &prev, &dirty, &opts).expect("repeat of a feasible repair");
        assert_eq!(warm.assignment, again.assignment, "seed {seed}: assignment diverged");
        assert_eq!(
            warm.cost.to_bits(),
            again.cost.to_bits(),
            "seed {seed}: cost bits diverged"
        );
    }
    assert!(scenarios >= 20, "only {scenarios} feasible churn scenarios; need >= 20");
}

#[test]
fn warm_resolve_errs_when_cold_would() {
    let opts = SolveOptions::heuristic();
    let base = InstanceBuilder::random(N, M, 99).t_min(T_MIN).build();
    let prev = solve(&base, &opts).expect("base instance solves");
    let mut dead = base.clone();
    for j in 0..M {
        dead.r[j] = 0.0;
    }
    dead.meta = Default::default();
    assert!(solve(&dead, &opts).is_err());
    assert!(resolve(&dead, &prev, &DirtySet::all(N, M), &opts).is_err());
}
