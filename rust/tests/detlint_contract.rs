//! Table-drift guard (same pattern as `registry_contract.rs` for §5):
//! DESIGN.md §9, `rust/lint.toml`, and `analysis::rules::NAMES` must
//! mirror each other exactly. Every zone in the manifest needs a doc row
//! in the §9 zone table, every rule needs a doc row in the §9 rule
//! table, and vice versa — so neither the docs nor the manifest can
//! silently rot as rules or zones are added, renamed, or dropped.

use std::collections::BTreeSet;
use std::path::Path;

use hflop::analysis::{rules, LintManifest, Severity};

fn design_md() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../DESIGN.md");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// The §9 section body (from its header to the next `## §` or EOF).
fn section9(text: &str) -> &str {
    let start = text.find("## §9").expect("DESIGN.md lost its §9 header");
    let rest = &text[start..];
    let end = rest[5..].find("\n## §").map(|i| i + 5).unwrap_or(rest.len());
    &rest[..end]
}

/// Backticked first cells of the §9 table body rows (`| \`name\` | ... |`) —
/// the union of the zone table and the rule table.
fn documented_cells(sec: &str) -> BTreeSet<String> {
    sec.lines()
        .filter_map(|line| {
            let line = line.trim();
            let rest = line.strip_prefix("| `")?;
            let name = rest.split('`').next()?;
            Some(name.to_string())
        })
        .collect()
}

fn manifest() -> LintManifest {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint.toml");
    LintManifest::load(&path).expect("parse rust/lint.toml")
}

#[test]
fn design_section9_mirrors_manifest_zones_and_rule_set() {
    let text = design_md();
    let documented = documented_cells(section9(&text));
    let m = manifest();

    let mut expected: BTreeSet<String> = m.zones.iter().cloned().collect();
    expected.extend(rules::names().iter().map(|s| s.to_string()));

    let undocumented: Vec<&String> = expected.difference(&documented).collect();
    assert!(
        undocumented.is_empty(),
        "zones/rules missing from the DESIGN.md §9 tables: {undocumented:?}"
    );
    let stale: Vec<&String> = documented.difference(&expected).collect();
    assert!(
        stale.is_empty(),
        "DESIGN.md §9 documents zones/rules that no longer exist: {stale:?}"
    );
    // Zone names and rule names must not collide, or the two tables
    // would be ambiguous to this guard.
    assert_eq!(documented.len(), m.zones.len() + rules::names().len());
}

#[test]
fn manifest_covers_every_rule_and_stays_deny() {
    let m = manifest();
    // The committed policy: every rule is deny severity. Loosening one
    // to warn/allow is a deliberate contract change — update §9 and
    // this test together.
    for rule in rules::names() {
        assert_eq!(
            m.severity_of(rule),
            Severity::Deny,
            "lint.toml severity for '{rule}' is no longer deny"
        );
    }
}

#[test]
fn design_section9_documents_the_oracle_exclusion_and_escape_hatch() {
    let text = design_md();
    let sec = section9(&text);
    for needle in ["sim/oracle.rs", "detlint: allow(", "hflop lint", "util::clock"] {
        assert!(sec.contains(needle), "DESIGN.md §9 no longer mentions '{needle}'");
    }
    // The manifest's exclusion list and the §9 prose must agree.
    let m = manifest();
    for ex in &m.exclude {
        assert!(sec.contains(ex.as_str()), "§9 does not mention exclusion '{ex}'");
    }
}
