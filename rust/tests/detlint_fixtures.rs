//! Fixture tests for the detlint analysis pass (`hflop lint`).
//!
//! Each fixture is a small Rust snippet fed straight through
//! [`hflop::analysis::rules::scan`]; the assertions pin which rules
//! fire, where (line:col), and which escape hatches are honoured. The
//! final test runs the real manifest over the real source tree — the
//! same scan `hflop lint` performs — and requires zero deny findings,
//! so a regression in any deterministic zone fails `cargo test` before
//! it ever reaches CI.

use std::path::Path;

use hflop::analysis::rules::scan;
use hflop::analysis::{lint_tree, LintManifest};

/// The rule names that fired, in reported (line, col) order.
fn rules_of(src: &str) -> Vec<&'static str> {
    scan(src).into_iter().map(|f| f.rule).collect()
}

// ---- wall-clock -----------------------------------------------------------

#[test]
fn wall_clock_instant_and_systemtime_flagged() {
    let src = "fn f() { let t = std::time::Instant::now(); }\n\
               fn g() { let s = std::time::SystemTime::now(); }\n";
    assert_eq!(rules_of(src), ["wall-clock", "wall-clock"]);
}

#[test]
fn wall_clock_allow_directive_suppresses() {
    let src = "// detlint: allow(wall-clock) -- sanctioned measurement shim\n\
               fn f() { let t = std::time::Instant::now(); }\n";
    assert!(rules_of(src).is_empty(), "allow on previous line must suppress");

    let inline = "fn f() { let t = std::time::Instant::now(); } \
                  // detlint: allow(wall-clock) -- same-line escape\n";
    assert!(rules_of(inline).is_empty(), "same-line allow must suppress");
}

#[test]
fn wall_clock_clean_code_passes() {
    let src = "fn f() { let clock = crate::util::WallClock::start(); \
               let dt = clock.elapsed_s(); }\n";
    assert!(rules_of(src).is_empty());
}

// ---- hash-iteration -------------------------------------------------------

#[test]
fn hash_containers_flagged_with_position() {
    let src = "use std::collections::HashMap;\n\
               fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let found = scan(src);
    assert_eq!(found.len(), 3, "one per HashMap mention: {found:?}");
    assert!(found.iter().all(|f| f.rule == "hash-iteration"));
    // `HashMap` in line 1 starts at column 23 (1-based).
    assert_eq!((found[0].line, found[0].col), (1, 23));
}

#[test]
fn hash_mention_in_comment_or_string_not_flagged() {
    let src = "// HashMap iteration order is why we use BTreeMap here\n\
               fn f() -> &'static str { \"HashMap HashSet Instant thread_rng\" }\n";
    assert!(rules_of(src).is_empty(), "comments and strings are opaque");
}

#[test]
fn hash_container_in_cfg_test_not_flagged() {
    let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n\
               \n    fn scratch() { let s: HashSet<u32> = HashSet::new(); }\n}\n";
    assert!(rules_of(src).is_empty(), "test-only code may use hash containers");
    // ...but cfg(not(test)) is production code and stays in scope.
    let prod = "#[cfg(not(test))]\nfn f() { let s = std::collections::HashSet::<u32>::new(); }\n";
    assert_eq!(rules_of(prod), ["hash-iteration"]);
}

// ---- float-partial-cmp ----------------------------------------------------

#[test]
fn partial_cmp_comparator_flagged_but_trait_impl_exempt() {
    let bad = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    assert_eq!(rules_of(bad), ["float-partial-cmp"]);

    let exempt = "impl PartialOrd for Node {\n\
                      fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                          Some(self.cmp(other))\n    }\n}\n";
    assert!(rules_of(exempt).is_empty(), "a PartialOrd impl is not a comparator");

    let clean = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
    assert!(rules_of(clean).is_empty());
}

// ---- unseeded-rng ---------------------------------------------------------

#[test]
fn unseeded_rng_sources_flagged() {
    let src = "fn f() { let mut r = thread_rng(); }\n\
               fn g() { let r = SmallRng::from_entropy(); }\n\
               fn h() { let v: u64 = rand::random(); }\n\
               fn k() { let r = StdRng::default(); }\n";
    assert_eq!(
        rules_of(src),
        ["unseeded-rng", "unseeded-rng", "unseeded-rng", "unseeded-rng"]
    );
}

#[test]
fn seeded_rng_and_unrelated_default_pass() {
    let src = "fn f() { let r = crate::util::rng::Rng::new(42); }\n\
               fn g() { let o: Options = Options::default(); }\n\
               fn h(m: &Map) { let v = m.random_field; }\n";
    assert!(rules_of(src).is_empty());
}

// ---- float-cast -----------------------------------------------------------

#[test]
fn unguarded_float_to_usize_cast_flagged() {
    let bad = "fn f(q: f64) -> usize { q.floor() as usize }\n";
    assert_eq!(rules_of(bad), ["float-cast"]);

    let bad2 = "fn f(n: usize, frac: f64) -> usize { (n as f64 * frac).ceil() as usize }\n";
    assert_eq!(rules_of(bad2), ["float-cast"]);
}

#[test]
fn guarded_or_integer_casts_pass() {
    let src = "fn f(q: f64) -> usize { q.floor().max(0.0) as usize }\n\
               fn g(q: f64, n: usize) -> usize { (q.clamp(0.0, n as f64)) as usize }\n\
               fn h(x: u32) -> usize { x as usize }\n\
               fn k(m: u128) -> usize { (m >> 64) as usize }\n";
    assert!(rules_of(src).is_empty());
}

// ---- malformed-allow ------------------------------------------------------

#[test]
fn malformed_allow_directives_are_findings() {
    // Missing the `-- reason` justification.
    let no_reason = "// detlint: allow(wall-clock)\n\
                     fn f() { let t = std::time::Instant::now(); }\n";
    let rules = rules_of(no_reason);
    assert!(rules.contains(&"malformed-allow"), "missing reason: {rules:?}");
    assert!(rules.contains(&"wall-clock"), "broken directive must not suppress");

    // Missing the rule name entirely.
    let no_rule = "// detlint: please ignore this one\nfn f() {}\n";
    assert_eq!(rules_of(no_rule), ["malformed-allow"]);
}

#[test]
fn allow_for_wrong_rule_or_stale_line_does_not_suppress() {
    let wrong_rule = "// detlint: allow(hash-iteration) -- wrong rule\n\
                      fn f() { let t = std::time::Instant::now(); }\n";
    assert_eq!(rules_of(wrong_rule), ["wall-clock"]);

    let too_far = "// detlint: allow(wall-clock) -- two lines above the finding\n\
                   fn unrelated() {}\n\
                   fn f() { let t = std::time::Instant::now(); }\n";
    assert_eq!(rules_of(too_far), ["wall-clock"], "allows reach one line, not two");
}

// ---- positions ------------------------------------------------------------

#[test]
fn findings_report_one_based_line_and_col() {
    let src = "\n\nfn f() {\n    let t = Instant::now();\n}\n";
    let found = scan(src);
    assert_eq!(found.len(), 1);
    // `Instant` sits on line 4, column 13 (1-based, after 4 spaces + `let t = `).
    assert_eq!((found[0].line, found[0].col), (4, 13), "{found:?}");
}

// ---- the real tree --------------------------------------------------------

/// The acceptance gate: the committed manifest over the committed source
/// tree has zero deny-severity findings. This is exactly what
/// `hflop lint` runs, so this test green means the CI lint job's detlint
/// step is green too.
#[test]
fn self_scan_real_tree_has_zero_deny_findings() {
    let base = Path::new(env!("CARGO_MANIFEST_DIR"));
    let manifest = LintManifest::load(&base.join("lint.toml")).expect("parse rust/lint.toml");
    let report = lint_tree(&manifest, base).expect("walk rust/src");
    assert!(
        report.files_in_zones >= 20,
        "zone walk looks truncated: only {} files in zones",
        report.files_in_zones
    );
    assert_eq!(
        report.deny_count(),
        0,
        "deny findings on the committed tree:\n{}",
        report.render()
    );
}
