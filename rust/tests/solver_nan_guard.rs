//! Source-level regression guard: PR 1 swept the solver stack's sorts
//! onto `f64::total_cmp`, and PR 3 fixed the last straggler in
//! `solver/lp.rs`. PR 7 replaced the original grep scan with the
//! detlint analyzer — the token-level `float-partial-cmp` rule knows
//! the one legitimate mention (`fn partial_cmp` inside a `PartialOrd`
//! impl, e.g. `solver::bb`'s heap entry) from a NaN-unsafe comparator:
//! `partial_cmp` returns `None` on NaN, and the customary `.unwrap()`
//! turns one poisoned cost into a panic mid-solve.

use std::fs;
use std::path::Path;

use hflop::analysis::rules::scan;

#[test]
fn no_partial_cmp_comparators_in_solver_sources() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/solver");
    let mut scanned = 0usize;
    let mut offenders = Vec::new();
    for entry in fs::read_dir(&dir).expect("read src/solver") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        scanned += 1;
        let text = fs::read_to_string(&path).expect("read solver source");
        for f in scan(&text) {
            if f.rule != "float-partial-cmp" {
                continue; // the other zone rules are covered by the self-scan
            }
            offenders.push(format!("{}:{}:{}: {}", path.display(), f.line, f.col, f.note));
        }
    }
    assert!(scanned >= 5, "expected the solver module tree, found {scanned} files");
    assert!(
        offenders.is_empty(),
        "NaN-unsafe comparator(s) in solver sources (use f64::total_cmp):\n{}",
        offenders.join("\n")
    );
}
