//! Source-level regression guard: PR 1 swept the solver stack's sorts
//! onto `f64::total_cmp`, and PR 3 fixed the last straggler in
//! `solver/lp.rs`. This test greps the solver sources so a NaN-unsafe
//! comparator (`partial_cmp(..).unwrap()` inside a sort/min/max) cannot
//! silently come back: `partial_cmp` returns `None` on NaN, and the
//! unwrap turns one poisoned cost into a panic mid-solve.

use std::fs;
use std::path::Path;

/// Lines that may legitimately mention `partial_cmp`: a `PartialOrd`
/// impl forwarding to a total order (e.g. `solver::bb`'s heap entry).
fn is_allowed(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.starts_with("fn partial_cmp(")
}

#[test]
fn no_partial_cmp_comparators_in_solver_sources() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/solver");
    let mut scanned = 0usize;
    let mut offenders = Vec::new();
    for entry in fs::read_dir(&dir).expect("read src/solver") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        scanned += 1;
        let text = fs::read_to_string(&path).expect("read solver source");
        for (lineno, line) in text.lines().enumerate() {
            if !line.contains("partial_cmp") || is_allowed(line) {
                continue;
            }
            // A comparator built from partial_cmp — whether in sort_by,
            // max_by, min_by or a hand-rolled closure — is the NaN hazard.
            offenders.push(format!("{}:{}: {}", path.display(), lineno + 1, line.trim()));
        }
    }
    assert!(scanned >= 5, "expected the solver module tree, found {scanned} files");
    assert!(
        offenders.is_empty(),
        "NaN-unsafe comparator(s) in solver sources (use f64::total_cmp):\n{}",
        offenders.join("\n")
    );
}
