//! End-to-end determinism of the registry-driven sweep engine: the same
//! grid + root seed must produce a byte-identical `SweepMatrix` JSON at
//! 1, 2 and 8 workers — including when an injected slow cell scrambles
//! the order in which workers finish. Per-cell RNG is hashed from axis
//! coordinate words, and every cell resolves and runs its experiment
//! through the registry on the worker thread, so nothing about
//! scheduling can leak into the results.

use hflop::config::params::Value;
use hflop::experiments::sweep::{run_grid, run_grid_with_hook, AxisPoint, SweepGrid};

/// A ≥24-cell interference grid over a small world with a short
/// horizon: every axis exercised (all four presets, both solver
/// engines, two environments), small enough to run repeatedly.
fn grid() -> SweepGrid {
    let mut g = SweepGrid::interference(2026);
    g.set_base("clients", Value::Int(12));
    g.set_base("edges", Value::Int(3));
    g.set_base("duration_s", Value::Float(25.0));
    g.set_base("lambda_scale", Value::Float(0.5));
    g.duration_s = 25.0;
    g
}

#[test]
fn matrix_json_bit_identical_at_1_2_and_8_workers() {
    let g = grid();
    assert!(g.n_cells() >= 24, "{} cells", g.n_cells());
    let serial = run_grid(&g, 1).unwrap().to_json().to_pretty();
    for workers in [2, 8] {
        let parallel = run_grid(&g, workers).unwrap().to_json().to_pretty();
        assert_eq!(serial.as_bytes(), parallel.as_bytes(), "matrix diverged at {workers} workers");
    }
}

#[test]
fn slow_cell_scrambles_completion_order_but_not_the_matrix() {
    let g = grid();
    let serial = run_grid(&g, 1).unwrap().to_json().to_pretty();
    // Cell 0 sleeps long enough that (with 8 workers) most other cells
    // complete before it — the merge must still land it in slot 0.
    let slowed = run_grid_with_hook(&g, 8, |i| {
        if i == 0 {
            std::thread::sleep(std::time::Duration::from_millis(250));
        }
    })
    .unwrap();
    assert_eq!(serial.as_bytes(), slowed.to_json().to_pretty().as_bytes());
    assert_eq!(slowed.cells[0].row, 0);
    assert_eq!(slowed.cells[0].seed_idx, 0);
}

#[test]
fn different_root_seed_changes_cells() {
    let a = run_grid(&SweepGrid { root_seed: 1, ..grid() }, 2).unwrap();
    let b = run_grid(&SweepGrid { root_seed: 2, ..grid() }, 2).unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    assert!(
        a.cells.iter().zip(&b.cells).any(|(x, y)| x.cell_seed != y.cell_seed),
        "root seed did not reach the cells"
    );
    assert_ne!(
        a.to_json().to_pretty(),
        b.to_json().to_pretty(),
        "different roots produced identical sweeps"
    );
}

#[test]
fn every_cell_simulated_real_traffic() {
    let m = run_grid(&grid(), 8).unwrap();
    for c in &m.cells {
        assert!(c.requests > 100, "cell {} looks empty ({} requests)", c.label, c.requests);
        assert!(c.mean_ms.is_finite() && c.mean_ms > 0.0, "cell {}", c.label);
        assert!(c.p50_ms <= c.p99_ms, "cell {} percentiles inverted", c.label);
        // Every co-sim cell actually trained on the timeline.
        assert!(c.rounds_completed >= 1, "cell {} completed no round", c.label);
    }
}

#[test]
fn trace_env_axis_is_deterministic_and_moves_traffic() {
    // The CLI spelling `hflop sweep --experiment interference
    //   --rows preset=steady,diurnal-surge --envs trace=none,diurnal,flash-crowd`
    // builds exactly this grid (see `run_sweep` in main.rs): open-loop
    // arrival traces are just another hashed env axis, so the byte-
    // identity contract must hold across worker counts — chunked
    // thinning generation runs on the worker thread from the cell seed.
    let trace_env = |name: &str| {
        AxisPoint::hashed(
            "interference",
            name,
            vec![("trace".to_string(), Value::Str(name.into()))],
        )
    };
    let g = SweepGrid::custom(
        "interference",
        vec![
            ("clients".to_string(), Value::Int(12)),
            ("edges".to_string(), Value::Int(3)),
            ("duration_s".to_string(), Value::Float(25.0)),
            ("lambda_scale".to_string(), Value::Float(0.5)),
        ],
        vec![
            AxisPoint::hashed(
                "interference",
                "steady",
                vec![("preset".to_string(), Value::Str("steady".into()))],
            ),
            AxisPoint::hashed(
                "interference",
                "diurnal-surge",
                vec![("preset".to_string(), Value::Str("diurnal-surge".into()))],
            ),
        ],
        vec![AxisPoint::neutral("base")],
        vec![trace_env("none"), trace_env("diurnal"), trace_env("flash-crowd")],
        1,
        7,
    )
    .unwrap();
    assert_eq!(g.n_cells(), 6);
    let serial = run_grid(&g, 1).unwrap();
    let serial_json = serial.to_json().to_pretty();
    let parallel = run_grid(&g, 8).unwrap().to_json().to_pretty();
    assert_eq!(serial_json.as_bytes(), parallel.as_bytes(), "trace envs broke determinism");

    // The trace envs must actually change the traffic, not just relabel
    // it: both open-loop shapes peak above the closed-loop base rate.
    let requests = |env_idx: usize| -> u64 {
        serial.cells.iter().filter(|c| c.env_idx == env_idx).map(|c| c.requests).sum()
    };
    let (closed, diurnal, flash) = (requests(0), requests(1), requests(2));
    assert!(closed > 100, "closed-loop cells look empty ({closed})");
    assert!(diurnal > closed, "diurnal trace did not add volume ({diurnal} vs {closed})");
    assert!(flash > closed, "flash-crowd trace did not add volume ({flash} vs {closed})");
}

#[test]
fn budget_grid_is_bit_identical_at_1_and_8_workers() {
    // The budget control plane adds stateful gating (token-bucket
    // refills, deferral queues) to every cell: the byte-identity
    // contract must survive it. Shrunk variant of `SweepGrid::budget` —
    // still covering an unlimited row, a starving cap row, both fault
    // rates and a surge env.
    let mut g = SweepGrid::budget(2026);
    g.set_base("clients", Value::Int(10));
    g.set_base("duration_s", Value::Float(30.0));
    g.set_base("lambda_scale", Value::Float(0.5));
    g.duration_s = 30.0;
    g.n_seeds = 1;
    g.rows.truncate(2); // unlimited + cap8
    g.envs.truncate(2);
    assert!(g.n_cells() >= 8, "{} cells", g.n_cells());
    let serial = run_grid(&g, 1).unwrap();
    let serial_json = serial.to_json().to_pretty();
    for workers in [8] {
        let parallel = run_grid(&g, workers).unwrap().to_json().to_pretty();
        assert_eq!(
            serial_json.as_bytes(),
            parallel.as_bytes(),
            "budget grid diverged at {workers} workers"
        );
    }
    // The budget keys actually flow into the matrix: every cell carries
    // a finite regret, and the governed cells meter spend or defer.
    for c in &serial.cells {
        assert!(c.regret_ms.is_finite(), "cell {}", c.label);
        assert!(c.requests > 100, "cell {} looks empty", c.label);
    }
}

#[test]
fn custom_registry_grid_is_deterministic_too() {
    // The declarative path new experiments use: sweep `fig7` cells via
    // hashed axis coordinates — same byte-identity contract.
    let g = SweepGrid::custom(
        "fig7",
        vec![
            ("clients".to_string(), Value::Int(12)),
            ("edges".to_string(), Value::Int(3)),
            ("duration_s".to_string(), Value::Float(15.0)),
        ],
        vec![
            AxisPoint::hashed(
                "fig7",
                "flat",
                vec![("setup".to_string(), Value::Str("flat".into()))],
            ),
            AxisPoint::hashed(
                "fig7",
                "hflop",
                vec![("setup".to_string(), Value::Str("hflop".into()))],
            ),
        ],
        vec![AxisPoint::neutral("auto")],
        vec![
            AxisPoint::hashed("fig7", "base", vec![]),
            AxisPoint::hashed(
                "fig7",
                "sp0.50",
                vec![("speedup".to_string(), Value::Float(0.5))],
            ),
        ],
        2,
        11,
    )
    .unwrap();
    assert_eq!(g.n_cells(), 8);
    let serial = run_grid(&g, 1).unwrap().to_json().to_pretty();
    let parallel = run_grid(&g, 8).unwrap().to_json().to_pretty();
    assert_eq!(serial.as_bytes(), parallel.as_bytes());
}
