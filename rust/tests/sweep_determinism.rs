//! End-to-end determinism of the sweep engine: the same grid + root
//! seed must produce a byte-identical `SweepMatrix` JSON at 1, 2 and 8
//! workers — including when an injected slow cell scrambles the order
//! in which workers finish. Per-cell RNG is hashed from grid
//! coordinates, so nothing about scheduling can leak into the results.

use hflop::experiments::interference::Preset;
use hflop::experiments::scenario::ScenarioConfig;
use hflop::experiments::sweep::{
    run_grid, run_grid_with_hook, EnvSpec, RowSpec, StaticSetup, SweepGrid, Workload,
};
use hflop::solver::LsMode;

/// A ≥24-cell grid over a small world with a short horizon: big enough
/// to exercise every axis (static + co-sim rows, both solver engines,
/// two environments), small enough to run repeatedly in one test file.
fn grid() -> SweepGrid {
    SweepGrid {
        scenario: ScenarioConfig {
            n_clients: 12,
            n_edges: 3,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        },
        rows: vec![
            RowSpec { name: "flat", workload: Workload::Static(StaticSetup::Flat) },
            RowSpec { name: "hflop", workload: Workload::Static(StaticSetup::Hflop) },
            RowSpec { name: "steady", workload: Workload::Cosim(Preset::Steady) },
            RowSpec { name: "edge-failure", workload: Workload::Cosim(Preset::EdgeFailure) },
        ],
        n_seeds: 2,
        modes: vec![LsMode::Completion, LsMode::Incremental],
        envs: vec![
            EnvSpec { name: "if0.25".into(), lambda_scale: 0.5, ..Default::default() },
            EnvSpec {
                name: "if1.0".into(),
                interference_factor: 1.0,
                lambda_scale: 0.5,
                ..Default::default()
            },
        ],
        duration_s: 25.0,
        ..SweepGrid::interference(2026)
    }
}

#[test]
fn matrix_json_bit_identical_at_1_2_and_8_workers() {
    let g = grid();
    assert!(g.n_cells() >= 24, "{} cells", g.n_cells());
    let serial = run_grid(&g, 1).unwrap().to_json().to_pretty();
    for workers in [2, 8] {
        let parallel = run_grid(&g, workers).unwrap().to_json().to_pretty();
        assert_eq!(serial.as_bytes(), parallel.as_bytes(), "matrix diverged at {workers} workers");
    }
}

#[test]
fn slow_cell_scrambles_completion_order_but_not_the_matrix() {
    let g = grid();
    let serial = run_grid(&g, 1).unwrap().to_json().to_pretty();
    // Cell 0 sleeps long enough that (with 8 workers) most other cells
    // complete before it — the merge must still land it in slot 0.
    let slowed = run_grid_with_hook(&g, 8, |i| {
        if i == 0 {
            std::thread::sleep(std::time::Duration::from_millis(250));
        }
    })
    .unwrap();
    assert_eq!(serial.as_bytes(), slowed.to_json().to_pretty().as_bytes());
    assert_eq!(slowed.cells[0].row, 0);
    assert_eq!(slowed.cells[0].seed_idx, 0);
}

#[test]
fn different_root_seed_changes_cells() {
    let a = run_grid(&SweepGrid { root_seed: 1, ..grid() }, 2).unwrap();
    let b = run_grid(&SweepGrid { root_seed: 2, ..grid() }, 2).unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    assert!(
        a.cells.iter().zip(&b.cells).any(|(x, y)| x.cell_seed != y.cell_seed),
        "root seed did not reach the cells"
    );
    assert_ne!(
        a.to_json().to_pretty(),
        b.to_json().to_pretty(),
        "different roots produced identical sweeps"
    );
}

#[test]
fn every_cell_simulated_real_traffic() {
    let m = run_grid(&grid(), 8).unwrap();
    for c in &m.cells {
        assert!(c.requests > 100, "cell {} looks empty ({} requests)", c.label, c.requests);
        assert!(c.mean_ms.is_finite() && c.mean_ms > 0.0, "cell {}", c.label);
        assert!(c.p50_ms <= c.p99_ms, "cell {} percentiles inverted", c.label);
    }
    // Co-sim rows actually trained.
    assert!(
        m.cells.iter().filter(|c| c.row >= 2).all(|c| c.rounds_completed >= 1),
        "a co-sim cell completed no training round"
    );
}
