//! Determinism regression tests for the B&B solver (DESIGN.md §9).
//!
//! PR 7 made wall-clock termination opt-in: `BbOptions::time_limit_s`
//! is `None` by default and deterministic `SolveOptions` reject it
//! outright. These tests pin the contract from both sides:
//!
//! (a) repeated solves of the same instance — including node-budget-bound
//!     runs that terminate *without* proving optimality — return
//!     bit-identical incumbents, costs, and node counts;
//! (b) `solve` / `solve_sparse` refuse `Some(time_limit_s)` while
//!     `deterministic` is set, and accept it once it is opted out.

use hflop::hflop::{InstanceBuilder, SparseInstance};
use hflop::solver::{branch_and_bound, solve, solve_sparse, BbOptions, SolveError, SolveOptions};

#[test]
fn repeated_solves_are_bit_identical() {
    for seed in [3u64, 11, 42] {
        let inst = InstanceBuilder::random(10, 4, seed).t_min(8).build();
        let opts = SolveOptions::exact();
        let Ok(a) = solve(&inst, &opts) else {
            continue; // infeasible draws are legitimate; skip
        };
        let b = solve(&inst, &opts).expect("second solve of a feasible instance");
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "seed {seed}: cost drifted");
        assert_eq!(a.nodes, b.nodes, "seed {seed}: explored tree drifted");
        assert_eq!(
            a.assignment.assign, b.assignment.assign,
            "seed {seed}: incumbent drifted"
        );
        assert_eq!(a.assignment.open, b.assignment.open, "seed {seed}");
    }
}

/// The determinism claim matters most when the budget binds: a run cut
/// off by `node_limit` returns best-so-far, and *which* incumbent that
/// is must depend only on the instance and the options — never on how
/// fast the machine happened to be.
#[test]
fn node_budget_bound_runs_return_identical_incumbents() {
    let mut unproven = 0usize;
    for seed in 0..10u64 {
        let n = 14 + (seed % 3) as usize;
        let inst = InstanceBuilder::random(n, 6, 70 + seed).t_min(n - 3).build();
        let opts = BbOptions { node_limit: 2, ..Default::default() };
        let a = branch_and_bound(&inst, &opts);
        let b = branch_and_bound(&inst, &opts);
        assert_eq!(a.nodes, b.nodes, "seed {seed}: explored different trees");
        assert_eq!(a.proven_optimal, b.proven_optimal, "seed {seed}");
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "seed {seed}: cost drifted");
        assert_eq!(
            a.best.map(|s| s.assign),
            b.best.map(|s| s.assign),
            "seed {seed}: best-so-far incumbent drifted between identical runs"
        );
        unproven += usize::from(!a.proven_optimal);
    }
    // The budget must actually have bound somewhere, or this pins nothing.
    assert!(unproven >= 1, "every seed proved within 2 nodes — cut node_limit");
}

#[test]
fn deterministic_mode_rejects_wall_clock_limit() {
    let inst = InstanceBuilder::random(8, 3, 1).t_min(6).build();
    let mut opts = SolveOptions::exact();
    opts.bb.time_limit_s = Some(30.0);
    let err = solve(&inst, &opts).expect_err("deterministic + time limit must be rejected");
    assert!(
        matches!(err, SolveError::Invalid(ref msg) if msg.contains("time_limit_s")),
        "wrong error: {err}"
    );

    // The sparse entry point enforces the same contract.
    let sp = SparseInstance::clustered(40, 4, 9, 3);
    let mut sp_opts = SolveOptions::auto();
    sp_opts.bb.time_limit_s = Some(30.0);
    let err = solve_sparse(&sp, &sp_opts).expect_err("solve_sparse must reject too");
    assert!(matches!(err, SolveError::Invalid(_)), "wrong error: {err}");
}

#[test]
fn opting_out_of_determinism_permits_wall_clock_limit() {
    let inst = InstanceBuilder::random(8, 3, 1).t_min(6).build();
    let mut opts = SolveOptions::exact();
    opts.deterministic = false;
    // A generous limit: the solve completes long before it, so the
    // result is still the optimum — we only exercise the config path.
    opts.bb.time_limit_s = Some(600.0);
    let sol = solve(&inst, &opts).expect("opted-out solve should run");
    assert!(sol.proven_optimal);
}
