//! Differential property test: the calendar-queue [`hflop::sim::Kernel`]
//! against the frozen binary-heap oracle [`hflop::sim::oracle::HeapKernel`].
//!
//! Both kernels are driven through the same randomized operation stream —
//! schedules across clustered and far-future timestamps, relative
//! schedules, tagged schedules, cancels, tag invalidations, peeks, pops
//! and occasional clears — and must agree *bit for bit*: identical
//! `(time, payload)` pop sequences (times compared via `to_bits`),
//! identical boolean/count returns from `cancel` / `invalidate_tag`, and
//! identical `processed` / `cancelled_count` / `len` counters throughout.
//!
//! The ordering contract ("deliver in `(time, seq)` order, FIFO at equal
//! timestamps") is thereby pinned by executable spec rather than prose:
//! any divergence between the two storage schemes fails loudly with the
//! op index that exposed it.

use hflop::sim::oracle::{HeapKernel, OracleTimerId};
use hflop::sim::{Kernel, TimerId};
use hflop::util::rng::Rng;

const TAGS: [u64; 4] = [7, 11, 13, 1 << 40];

/// Draw a scheduling timestamp offset from a mixture that stresses every
/// calendar tier: dense clusters (many entries per bucket, frequent
/// exact ties), a mid band (ordinary spread), and far-future outliers
/// (overflow tier, re-anchor churn).
fn draw_offset(rng: &mut Rng) -> f64 {
    match rng.below(10) {
        // Dense cluster just ahead of the clock; quantized so exact
        // timestamp ties are common and FIFO-at-ties is exercised.
        0..=4 => (rng.below(64) as f64) * 1e-4,
        // Mid band.
        5..=7 => rng.uniform(0.0, 50.0),
        // Far future: lands in the overflow tier until a re-anchor.
        _ => 1e6 + rng.uniform(0.0, 1e9),
    }
}

struct Pair {
    new: Kernel<u32>,
    old: HeapKernel<u32>,
    // Parallel handle vectors, indexed by issue order.
    new_ids: Vec<TimerId>,
    old_ids: Vec<OracleTimerId>,
}

impl Pair {
    fn fresh() -> Pair {
        Pair {
            new: Kernel::new(),
            old: HeapKernel::new(),
            new_ids: Vec::new(),
            old_ids: Vec::new(),
        }
    }

    fn check_counters(&self, op: usize) {
        assert_eq!(self.new.len(), self.old.len(), "len diverged at op {op}");
        assert_eq!(self.new.processed(), self.old.processed(), "processed diverged at op {op}");
        assert_eq!(
            self.new.cancelled_count(),
            self.old.cancelled_count(),
            "cancelled_count diverged at op {op}"
        );
        assert_eq!(
            self.new.now().to_bits(),
            self.old.now().to_bits(),
            "clock diverged at op {op}"
        );
    }
}

/// Drive both kernels through `n_ops` random operations and assert
/// bit-identical observable behaviour at every step.
fn differential_run(seed: u64, n_ops: usize) {
    let mut rng = Rng::new(seed);
    let mut p = Pair::fresh();
    let mut payload: u32 = 0;

    for op in 0..n_ops {
        match rng.below(100) {
            // Absolute-time schedule (the dominant operation).
            0..=39 => {
                let t = p.new.now() + draw_offset(&mut rng);
                payload += 1;
                p.new_ids.push(p.new.schedule(t, payload));
                p.old_ids.push(p.old.schedule(t, payload));
            }
            // Relative schedule, including clamped negative delays.
            40..=49 => {
                let d = draw_offset(&mut rng) - 0.5;
                payload += 1;
                p.new_ids.push(p.new.schedule_in(d, payload));
                p.old_ids.push(p.old.schedule_in(d, payload));
            }
            // Tagged schedule under one of a few rotating tags.
            50..=64 => {
                let t = p.new.now() + draw_offset(&mut rng);
                let tag = TAGS[rng.below(TAGS.len())];
                payload += 1;
                p.new_ids.push(p.new.schedule_tagged(t, tag, payload));
                p.old_ids.push(p.old.schedule_tagged(t, tag, payload));
            }
            // Cancel a previously issued handle (live, fired, already
            // cancelled, or tag-revoked — the return value must agree in
            // every case).
            65..=79 => {
                if p.new_ids.is_empty() {
                    continue;
                }
                let k = rng.below(p.new_ids.len());
                let a = p.new.cancel(p.new_ids[k]);
                let b = p.old.cancel(p.old_ids[k]);
                assert_eq!(a, b, "cancel return diverged at op {op} (handle {k})");
            }
            // Invalidate a tag generation.
            80..=84 => {
                let tag = TAGS[rng.below(TAGS.len())];
                let a = p.new.invalidate_tag(tag);
                let b = p.old.invalidate_tag(tag);
                assert_eq!(a, b, "invalidate_tag count diverged at op {op}");
                assert_eq!(p.new.generation(tag), p.old.generation(tag));
            }
            // Peek.
            85..=89 => {
                let a = p.new.peek_time().map(f64::to_bits);
                let b = p.old.peek_time().map(f64::to_bits);
                assert_eq!(a, b, "peek_time diverged at op {op}");
            }
            // Pop a burst of events.
            90..=97 => {
                for _ in 0..=rng.below(8) {
                    let a = p.new.next().map(|(t, e)| (t.to_bits(), e));
                    let b = p.old.next().map(|(t, e)| (t.to_bits(), e));
                    assert_eq!(a, b, "pop diverged at op {op}");
                    if a.is_none() {
                        break;
                    }
                }
            }
            // Bounded pop.
            98 => {
                let h = p.new.now() + rng.uniform(0.0, 10.0);
                let a = p.new.next_before(h).map(|(t, e)| (t.to_bits(), e));
                let b = p.old.next_before(h).map(|(t, e)| (t.to_bits(), e));
                assert_eq!(a, b, "next_before diverged at op {op}");
            }
            // Rare wholesale clear (retention contract: counters and tag
            // generations survive on both sides).
            _ => {
                p.new.clear();
                p.old.clear();
            }
        }
        p.check_counters(op);
    }

    // Drain both queues to the end: the full residual pop sequence must
    // match bit for bit.
    loop {
        let a = p.new.next().map(|(t, e)| (t.to_bits(), e));
        let b = p.old.next().map(|(t, e)| (t.to_bits(), e));
        assert_eq!(a, b, "drain diverged");
        if a.is_none() {
            break;
        }
    }
    p.check_counters(n_ops);
}

#[test]
fn calendar_kernel_matches_heap_oracle_over_random_ops() {
    // ~10k ops per seed; several seeds so clustered/far-future mixtures,
    // re-anchors and growth rebuilds all get distinct interleavings.
    // Smoke mode (the nightly Miri job runs this test under the
    // interpreter at ~100x slowdown) trims to one seed and ~1k ops —
    // still enough to cross bucket-growth and re-anchor paths.
    let (seeds, n_ops): (&[u64], usize) = if hflop::util::smoke_mode() {
        (&[1], 1_000)
    } else {
        (&[1, 2026, 0xC0FFEE], 10_000)
    };
    for &seed in seeds {
        differential_run(seed, n_ops);
    }
}

#[test]
fn calendar_kernel_matches_heap_oracle_with_many_distinct_tags() {
    // PR 7 moved the kernel's tag-generation table from HashMap to
    // BTreeMap; a wide tag universe (every schedule under its own tag,
    // interleaved invalidations) exercises the converted paths well past
    // the 4-tag rotation of the main differential stream.
    let mut rng = Rng::new(31);
    let mut new = Kernel::new();
    let mut old = HeapKernel::new();
    let n = if hflop::util::smoke_mode() { 400u64 } else { 2_000u64 };
    for i in 0..n {
        let t = (rng.below(64) as f64) * 0.125;
        new.schedule_tagged(t, i, i as u32);
        old.schedule_tagged(t, i, i as u32);
        if rng.chance(0.2) {
            let tag = rng.below(i as usize + 1) as u64;
            assert_eq!(new.invalidate_tag(tag), old.invalidate_tag(tag), "tag {tag}");
            assert_eq!(new.generation(tag), old.generation(tag));
        }
        if rng.chance(0.25) {
            let a = new.next().map(|(t, e)| (t.to_bits(), e));
            let b = old.next().map(|(t, e)| (t.to_bits(), e));
            assert_eq!(a, b);
        }
    }
    loop {
        let a = new.next().map(|(t, e)| (t.to_bits(), e));
        let b = old.next().map(|(t, e)| (t.to_bits(), e));
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
    assert_eq!(new.processed(), old.processed());
    assert_eq!(new.cancelled_count(), old.cancelled_count());
}

#[test]
fn calendar_kernel_matches_heap_oracle_under_heavy_ties() {
    // All-clustered workload: every timestamp is one of 16 values, so
    // almost every delivery decision is settled by the FIFO seq tiebreak.
    let mut rng = Rng::new(99);
    let mut new = Kernel::new();
    let mut old = HeapKernel::new();
    for i in 0..4_000u32 {
        let t = (rng.below(16) as f64) * 0.25;
        new.schedule(t, i);
        old.schedule(t, i);
        if rng.chance(0.3) {
            let a = new.next().map(|(t, e)| (t.to_bits(), e));
            let b = old.next().map(|(t, e)| (t.to_bits(), e));
            assert_eq!(a, b);
        }
    }
    loop {
        let a = new.next().map(|(t, e)| (t.to_bits(), e));
        let b = old.next().map(|(t, e)| (t.to_bits(), e));
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
    assert_eq!(new.processed(), old.processed());
}
