//! Integration: load the AOT artifacts through PJRT and check numerics
//! against the jax oracle (`artifacts/oracle_small.json`, produced by
//! `make artifacts`). This is the cross-language contract test: if it
//! passes, the rust coordinator is executing exactly the computation the
//! python/Pallas stack defined.

use std::path::PathBuf;

use hflop::runtime::{Engine, Manifest, Preload};
use hflop::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

struct Oracle {
    lr: f32,
    x_train: Vec<f32>,
    y_train: Vec<f32>,
    x_pred: Vec<f32>,
    pred: Vec<f32>,
    x_eval: Vec<f32>,
    y_eval: Vec<f32>,
    mse: f32,
    train_loss: f32,
    new_params_first: Vec<f32>,
    new_params_last: Vec<f32>,
}

fn load_oracle(dir: &PathBuf, file: &str) -> Oracle {
    let text = std::fs::read_to_string(dir.join(file)).expect("oracle file");
    let j = Json::parse(&text).expect("oracle json");
    let vecf = |k: &str| j.get(k).and_then(Json::as_f32_vec).expect(k);
    let num = |k: &str| j.get(k).and_then(Json::as_f64).expect(k) as f32;
    Oracle {
        lr: num("lr"),
        x_train: vecf("x_train"),
        y_train: vecf("y_train"),
        x_pred: vecf("x_pred"),
        pred: vecf("pred"),
        x_eval: vecf("x_eval"),
        y_eval: vecf("y_eval"),
        mse: num("mse"),
        train_loss: num("train_loss"),
        new_params_first: vecf("new_params_first"),
        new_params_last: vecf("new_params_last"),
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, want {w}"
        );
    }
}

#[test]
fn predict_matches_jax_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("small").unwrap();
    let oracle = load_oracle(&dir, variant.oracle_file.as_ref().unwrap());
    let params = manifest.load_init_params(variant).unwrap();

    let engine = Engine::new(&manifest, "small", Preload::All).unwrap();
    let got = engine.predict(&params, &oracle.x_pred).unwrap();
    assert_close(&got, &oracle.pred, 1e-4, "predict");
}

#[test]
fn train_step_matches_jax_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("small").unwrap();
    let oracle = load_oracle(&dir, variant.oracle_file.as_ref().unwrap());
    let params = manifest.load_init_params(variant).unwrap();

    let engine = Engine::new(&manifest, "small", Preload::Training).unwrap();
    let (new_params, loss) = engine
        .train_step(&params, &oracle.x_train, &oracle.y_train, oracle.lr)
        .unwrap();
    assert!((loss - oracle.train_loss).abs() < 1e-4, "loss {loss} vs {}", oracle.train_loss);

    // First and last parameter arrays pinned by the oracle.
    let first_len = oracle.new_params_first.len();
    assert_close(&new_params[..first_len], &oracle.new_params_first, 1e-4, "params[0]");
    let offsets = variant.offsets();
    let last_off = *offsets.last().unwrap();
    assert_close(&new_params[last_off..], &oracle.new_params_last, 1e-4, "params[-1]");
}

#[test]
fn eval_matches_jax_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("small").unwrap();
    let oracle = load_oracle(&dir, variant.oracle_file.as_ref().unwrap());
    let params = manifest.load_init_params(variant).unwrap();

    let engine = Engine::new(&manifest, "small", Preload::Training).unwrap();
    let mse = engine.eval_mse(&params, &oracle.x_eval, &oracle.y_eval).unwrap();
    assert!((mse - oracle.mse).abs() < 1e-4, "mse {mse} vs {}", oracle.mse);
}

#[test]
fn repeated_train_steps_reduce_loss_on_learnable_task() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("small").unwrap().clone();
    let mut params = manifest.load_init_params(&variant).unwrap();
    let engine = Engine::new(&manifest, "small", Preload::Training).unwrap();

    // Learnable toy task: y = mean of last 3 inputs.
    use hflop::util::rng::Rng;
    let mut rng = Rng::new(99);
    let (b, t) = (variant.train_batch, variant.seq_len);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..60 {
        let x: Vec<f32> = (0..b * t).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..b)
            .map(|i| {
                let w = &x[i * t..(i + 1) * t];
                (w[t - 3] + w[t - 2] + w[t - 1]) / 3.0
            })
            .collect();
        let (p, loss) = engine.train_step(&params, &x, &y, 0.05).unwrap();
        params = p;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < first * 0.7, "loss did not decrease: {first} -> {last}");
}

#[test]
fn batch_predict_consistent_with_single() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("small").unwrap().clone();
    let params = manifest.load_init_params(&variant).unwrap();
    let engine = Engine::new(&manifest, "small", Preload::Serving).unwrap();

    use hflop::util::rng::Rng;
    let mut rng = Rng::new(5);
    let t = variant.seq_len;
    let sb = variant.serve_batch;
    let xb: Vec<f32> = (0..sb * t).map(|_| rng.normal() as f32).collect();
    let batch = engine.predict_batch(&params, &xb).unwrap();
    assert_eq!(batch.len(), sb * variant.out_dim);
    for i in 0..sb {
        let single = engine.predict(&params, &xb[i * t..(i + 1) * t]).unwrap();
        for (a, b) in single.iter().zip(&batch[i * variant.out_dim..(i + 1) * variant.out_dim]) {
            assert!((a - b).abs() < 1e-5, "row {i}: {a} vs {b}");
        }
    }
}

#[test]
fn paper_variant_loads_and_predicts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let variant = manifest.variant("paper").unwrap().clone();
    let params = manifest.load_init_params(&variant).unwrap();
    assert_eq!(params.len(), 149_505); // 2-layer GRU(128) + head
    let engine = Engine::new(&manifest, "paper", Preload::Serving).unwrap();
    let x = vec![0.1f32; variant.seq_len];
    let out = engine.predict(&params, &x).unwrap();
    assert_eq!(out.len(), 1);
    assert!(out[0].is_finite());
}
